//! Plan serialization: compute offline, ship with the model, load at serve
//! time (what TFLite does with its prepacked arena plans).
//!
//! Hand-rolled line format (the offline registry has no serde): versioned,
//! self-describing, whitespace-tokenized, with a trailing checksum so a
//! truncated file never half-loads.
//!
//! ```text
//! tensorarena-plan v2 offset <n> <total> <order>
//! <record_id> <offset> <size> <first_op> <last_op>   # one per record
//! checksum <fnv1a of all prior lines>
//! ```
//!
//! The embedded `(size, first_op, last_op)` triples let the loader verify
//! the plan matches the records it is applied to — loading a stale plan
//! against a changed model fails loudly instead of corrupting tensors.
//! Every record id must appear **exactly once**: a file with a dropped or
//! duplicated record line is rejected even when its checksum is consistent
//! (FNV-1a is not cryptographic — anyone can recompute it), so a crafted
//! or mis-merged file can never half-load into a plan the planner never
//! produced.
//!
//! **v2** (the execution-order bump): the header carries `<order>`, the
//! canonical [`super::registry::OrderStrategy`] key the records were
//! extracted under. Orders change record lifetimes, so a plan is only
//! valid under the order that produced it; the loader rejects an order
//! mismatch ([`LoadError::OrderMismatch`]) and rejects pre-bump `v1` files
//! cleanly ([`LoadError::UnsupportedVersion`]) instead of mistaking their
//! total for an order key.
//!
//! # On-disk plan-directory format
//!
//! A *plan directory* persists a whole [`super::cache::PlanCache`] so a
//! restarted server warm-starts with zero planner invocations
//! ([`super::cache::PlanCache::persist_dir`] /
//! [`super::cache::PlanCache::warm_start`]). It is a flat directory with
//! one file per cache key:
//!
//! ```text
//! <dir>/
//!   <fingerprint>-<request>.plan       ; e.g. <fp>-b4-greedy-size@natural.plan
//! ```
//!
//! * `<fingerprint>` — 16 lowercase hex digits, [`records_fingerprint`] of
//!   the **batch-1** records (the plan-cache key fingerprint); for a
//!   non-natural order these are the records of the *reordered* graph;
//! * `<request>` — the canonical [`PlanRequest`] rendering
//!   (`b<batch>-<strategy>@<order>[~<dtype>]`, see [`super::request`] for
//!   the full grammar). Only **static** requests appear on disk; the
//!   `~<dtype>` segment appears only for non-f32 size classes (e.g.
//!   `…@natural~i8.plan`), so f32 names are byte-identical to the
//!   pre-redesign format and every pre-dtype directory parses as f32 and
//!   keeps warm-starting. v1-era file names (no `@<order>` segment) fail
//!   to parse and are skipped; an unrecognized dtype key is a typed
//!   forward-compatibility skip ([`ParseRequestError::UnknownDtype`]).
//!
//! Each file's *content* is the v2 text format above, serialized against
//! the batch-scaled records. Writers create files atomically (write to a
//! dot-prefixed, per-process `.<name>.<pid>.tmp` sibling, then rename) so
//! readers never see a torn file even when a fleet shares the directory;
//! loaders skip — never crash on, never serve — any file that
//! is truncated, checksum-corrupt, fingerprint-mismatched, names a
//! strategy that is no longer registered, or was written under a different
//! execution order, and count the skips.

use super::dynamic::DynamicRecords;
use super::request::{DynamicMode, ParseRequestError, PlanRequest};
use super::{OffsetPlan, SharedObjectPlan};
use crate::records::UsageRecords;

/// FNV-1a over bytes (stable, dependency-free). Also the hash behind
/// [`records_fingerprint`] and therefore the plan cache's keys.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a fingerprint of a record set — everything a planner consumes
/// (`num_ops` plus every `(first_op, last_op, size)` triple, in record
/// order). Two graphs with equal fingerprints get identical plans, which is
/// what lets `planner::cache::PlanCache` key on it.
pub fn records_fingerprint(records: &UsageRecords) -> u64 {
    let mut buf = Vec::with_capacity(8 + records.len() * 24);
    buf.extend_from_slice(&(records.num_ops as u64).to_le_bytes());
    for r in &records.records {
        buf.extend_from_slice(&(r.first_op as u64).to_le_bytes());
        buf.extend_from_slice(&(r.last_op as u64).to_le_bytes());
        buf.extend_from_slice(&(r.size as u64).to_le_bytes());
    }
    fnv1a(&buf)
}

/// FNV-1a fingerprint of the **resolved-size prefix** of a dynamic record
/// set: everything known under `mode` — the op count, every record's
/// interval and `known_at`, and the *sizes of the records resolved so far*
/// (statically-known records, `known_at == 0`, are resolved under every
/// [`DynamicMode`]). Unresolved sizes are replaced by a tag byte, so two
/// decode steps see the same fingerprint exactly when the same sizes have
/// resolved to the same values — the §7 plan-cache key dimension (see
/// [`super::cache::PlanCache::get_or_plan_dynamic`]). In particular,
/// `Resolved(op)` modes between the same wave boundaries — and
/// [`DynamicMode::FullyResolved`] versus a `Resolved(op)` past the last
/// boundary — fingerprint identically, which is what makes them share one
/// cache slot.
pub fn resolved_prefix_fingerprint(dynamic: &DynamicRecords, mode: DynamicMode) -> u64 {
    let mut buf = Vec::with_capacity(8 + dynamic.len() * 33);
    buf.extend_from_slice(&(dynamic.num_ops as u64).to_le_bytes());
    for d in &dynamic.records {
        buf.extend_from_slice(&(d.record.first_op as u64).to_le_bytes());
        buf.extend_from_slice(&(d.record.last_op as u64).to_le_bytes());
        buf.extend_from_slice(&(d.known_at as u64).to_le_bytes());
        if mode.resolves(d.known_at) {
            buf.push(1);
            buf.extend_from_slice(&(d.record.size as u64).to_le_bytes());
        } else {
            buf.push(0);
        }
    }
    fnv1a(&buf)
}

/// Serialize an offset plan together with the records it plans, stamping
/// the canonical key of `req`'s execution order into the v2 header.
/// `records` must be the batch- and dtype-scaled records the plan was
/// produced for (`base.scaled_for(req.batch(), req.dtype())`).
pub fn offset_plan_to_string(
    plan: &OffsetPlan,
    records: &UsageRecords,
    req: &PlanRequest,
) -> String {
    to_string_with_order(plan, records, &req.order().key())
}

/// [`offset_plan_to_string`] with a raw order key instead of a typed
/// request.
#[deprecated(since = "0.3.0", note = "build a PlanRequest and call offset_plan_to_string")]
pub fn offset_plan_to_string_ordered(
    plan: &OffsetPlan,
    records: &UsageRecords,
    order_key: &str,
) -> String {
    to_string_with_order(plan, records, order_key)
}

fn to_string_with_order(plan: &OffsetPlan, records: &UsageRecords, order_key: &str) -> String {
    debug_assert!(
        !order_key.is_empty() && !order_key.contains(char::is_whitespace),
        "order key must be a single token"
    );
    let mut body = format!(
        "tensorarena-plan v2 offset {} {} {order_key}\n",
        records.len(),
        plan.total
    );
    for r in &records.records {
        body.push_str(&format!(
            "{} {} {} {} {}\n",
            r.id, plan.offsets[r.id], r.size, r.first_op, r.last_op
        ));
    }
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Serialize a shared-objects plan.
pub fn shared_plan_to_string(plan: &SharedObjectPlan, records: &UsageRecords) -> String {
    let mut body = format!(
        "tensorarena-plan v1 shared {} {}\n",
        records.len(),
        plan.object_sizes.len()
    );
    body.push_str("objects");
    for s in &plan.object_sizes {
        body.push_str(&format!(" {s}"));
    }
    body.push('\n');
    for r in &records.records {
        body.push_str(&format!(
            "{} {} {} {} {}\n",
            r.id, plan.assignment[r.id], r.size, r.first_op, r.last_op
        ));
    }
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Errors while loading a plan.
#[derive(Debug, PartialEq, Eq)]
pub enum LoadError {
    /// The first line is not a well-formed `tensorarena-plan` header.
    BadHeader(String),
    /// The file speaks an older (or unknown) format version — e.g. a `v1`
    /// file written before the execution-order bump. Rejected cleanly
    /// rather than guessed at: v1 headers have no order field, so loading
    /// one as v2 would mis-key the plan.
    UnsupportedVersion(String),
    /// The trailing FNV-1a checksum does not match the body.
    BadChecksum,
    /// The checksum line (or more) is missing entirely.
    Truncated,
    /// A record line failed to tokenize into five integers (1-based line).
    Malformed(usize),
    /// The plan was produced for different records.
    RecordMismatch {
        /// Record id (or count) that mismatched.
        record: usize,
        /// Which field mismatched (`size`, `first_op`, `last_op`, `count`,
        /// `duplicate`, `missing`).
        field: &'static str,
    },
    /// The plan was produced under a different execution order (lifetimes
    /// differ, so its offsets are meaningless for these records).
    OrderMismatch {
        /// Canonical order key found in the file's header.
        found: String,
        /// Canonical order key of the loading configuration.
        expected: String,
    },
    /// The plan parsed but fails §5 feasibility (or declares an arena total
    /// above the records' naive bound).
    Infeasible(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::BadHeader(h) => write!(f, "bad plan header: {h}"),
            LoadError::UnsupportedVersion(v) => {
                write!(f, "unsupported plan format version '{v}' (this build reads v2)")
            }
            LoadError::BadChecksum => write!(f, "plan checksum mismatch"),
            LoadError::Truncated => write!(f, "plan file truncated"),
            LoadError::Malformed(line) => write!(f, "malformed plan line {line}"),
            LoadError::RecordMismatch { record, field } => {
                write!(f, "plan does not match records: record {record}, field {field}")
            }
            LoadError::OrderMismatch { found, expected } => {
                write!(f, "plan was produced under order '{found}', not '{expected}'")
            }
            LoadError::Infeasible(e) => write!(f, "loaded plan infeasible: {e}"),
        }
    }
}

impl std::error::Error for LoadError {}

fn split_checksum(text: &str) -> Result<(&str, u64), LoadError> {
    let body_end = text.rfind("checksum ").ok_or(LoadError::Truncated)?;
    let (body, tail) = text.split_at(body_end);
    let sum_hex = tail.trim_start_matches("checksum ").trim();
    let sum = u64::from_str_radix(sum_hex, 16).map_err(|_| LoadError::BadChecksum)?;
    Ok((body, sum))
}

/// Checksum-verified parse of a v2 offset-plan text: the declared total,
/// the order key, and, per record id, `(offset, size, first_op, last_op)`.
/// Every record id must appear exactly once — a file with a dropped or
/// duplicated line (checksummed consistently; FNV-1a is not cryptographic)
/// must never half-load into a plan the planner did not produce.
#[allow(clippy::type_complexity)]
fn parse_offset_plan(
    text: &str,
) -> Result<(usize, String, Vec<(usize, usize, usize, usize)>), LoadError> {
    let (body, sum) = split_checksum(text)?;
    if fnv1a(body.as_bytes()) != sum {
        return Err(LoadError::BadChecksum);
    }
    let mut lines = body.lines();
    let header = lines.next().ok_or(LoadError::Truncated)?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 2 || h[0] != "tensorarena-plan" {
        return Err(LoadError::BadHeader(header.to_string()));
    }
    if h[1] != "v2" {
        // A pre-bump (v1) or future-version file: reject by version, never
        // by guessing at its field layout.
        return Err(LoadError::UnsupportedVersion(h[1].to_string()));
    }
    if h.len() != 6 || h[2] != "offset" {
        return Err(LoadError::BadHeader(header.to_string()));
    }
    let n: usize = h[3].parse().map_err(|_| LoadError::BadHeader(header.into()))?;
    let total: usize = h[4].parse().map_err(|_| LoadError::BadHeader(header.into()))?;
    let order = h[5].to_string();
    // `n` is untrusted input: bound it by the actual number of record
    // lines (each record needs its own line) *before* allocating anything
    // proportional to it — a crafted header count must be a skippable
    // error for loaders, not a capacity-overflow abort.
    if n > lines.clone().count() {
        return Err(LoadError::RecordMismatch { record: n, field: "count" });
    }
    let mut rows: Vec<Option<(usize, usize, usize, usize)>> = vec![None; n];
    for (li, line) in lines.enumerate() {
        let f: Vec<usize> = line
            .split_whitespace()
            .map(|t| t.parse().map_err(|_| LoadError::Malformed(li + 2)))
            .collect::<Result<_, _>>()?;
        if f.len() != 5 {
            return Err(LoadError::Malformed(li + 2));
        }
        let (id, offset, size, first, last) = (f[0], f[1], f[2], f[3], f[4]);
        if id >= n {
            return Err(LoadError::Malformed(li + 2));
        }
        if rows[id].is_some() {
            return Err(LoadError::RecordMismatch { record: id, field: "duplicate" });
        }
        rows[id] = Some((offset, size, first, last));
    }
    rows.into_iter()
        .enumerate()
        .map(|(id, row)| row.ok_or(LoadError::RecordMismatch { record: id, field: "missing" }))
        .collect::<Result<Vec<_>, _>>()
        .map(|rows| (total, order, rows))
}

/// Load and verify an offset plan against `records`, additionally checking
/// that the plan was serialized under `req`'s execution order — a plan's
/// offsets are only meaningful for the record lifetimes of the order that
/// produced it. `records` must be the batch- and dtype-scaled records
/// (`base.scaled_for(req.batch(), req.dtype())`).
pub fn offset_plan_from_str(
    text: &str,
    records: &UsageRecords,
    req: &PlanRequest,
) -> Result<OffsetPlan, LoadError> {
    from_str_with_order(text, records, &req.order().key())
}

/// [`offset_plan_from_str`] with a raw order key instead of a typed
/// request.
#[deprecated(since = "0.3.0", note = "build a PlanRequest and call offset_plan_from_str")]
pub fn offset_plan_from_str_ordered(
    text: &str,
    records: &UsageRecords,
    expected_order: &str,
) -> Result<OffsetPlan, LoadError> {
    from_str_with_order(text, records, expected_order)
}

fn from_str_with_order(
    text: &str,
    records: &UsageRecords,
    expected_order: &str,
) -> Result<OffsetPlan, LoadError> {
    let (total, order, rows) = parse_offset_plan(text)?;
    if order != expected_order {
        return Err(LoadError::OrderMismatch {
            found: order,
            expected: expected_order.to_string(),
        });
    }
    if rows.len() != records.len() {
        return Err(LoadError::RecordMismatch { record: rows.len(), field: "count" });
    }
    // The declared total is untrusted too: feasibility only bounds it from
    // below (every tensor must fit), so an inflated total would pass every
    // record check yet poison budget queries and arena sizing. No registry
    // strategy ever exceeds the naive sum — reject anything above it.
    if total > records.naive_total() {
        return Err(LoadError::Infeasible(format!(
            "declared arena total {total} exceeds the records' naive bound {}",
            records.naive_total()
        )));
    }
    let mut offsets = vec![0usize; rows.len()];
    for (id, (offset, size, first, last)) in rows.into_iter().enumerate() {
        let r = &records.records[id];
        if r.size != size {
            return Err(LoadError::RecordMismatch { record: id, field: "size" });
        }
        if r.first_op != first {
            return Err(LoadError::RecordMismatch { record: id, field: "first_op" });
        }
        if r.last_op != last {
            return Err(LoadError::RecordMismatch { record: id, field: "last_op" });
        }
        offsets[id] = offset;
    }
    let plan = OffsetPlan { offsets, total };
    plan.validate(records)
        .map_err(|e| LoadError::Infeasible(e.to_string()))?;
    Ok(plan)
}

/// File name of one plan inside a plan directory (see the module docs):
/// `<fingerprint>-<request>.plan`, with `fingerprint` the **batch-1**
/// records fingerprint and `<request>` the [`PlanRequest`]'s canonical
/// [`Display`](std::fmt::Display) rendering — exactly the plan-cache key.
/// For static f32 requests this is byte-identical to the pre-redesign
/// `<fingerprint>-b<batch>-<strategy>@<order>.plan` grammar; non-f32 size
/// classes append their `~<dtype>` segment.
pub fn plan_file_name(fingerprint: u64, req: &PlanRequest) -> String {
    format!("{fingerprint:016x}-{req}.plan")
}

/// Parse a plan-directory file name back into `(fingerprint,
/// PlanRequest)` via the request's [`FromStr`](std::str::FromStr)
/// grammar. Errors distinguish unregistered strategy / order keys
/// ([`ParseRequestError::UnknownStrategy`] /
/// [`ParseRequestError::UnknownOrder`] — *stale* files, another build's
/// plans) from anything structurally wrong
/// ([`ParseRequestError::Malformed`] — including v1-era names without the
/// `@<order>` segment); loaders skip all of them, with different
/// counters.
pub fn parse_plan_file_name(name: &str) -> Result<(u64, PlanRequest), ParseRequestError> {
    let malformed = || ParseRequestError::Malformed(name.to_string());
    let stem = name.strip_suffix(".plan").ok_or_else(malformed)?;
    // Hex digits never contain '-', so the first '-' ends the fingerprint
    // and the remainder is exactly the request grammar.
    let (fp_hex, request) = stem.split_once('-').ok_or_else(malformed)?;
    if fp_hex.len() != 16 || !fp_hex.bytes().all(|b| b.is_ascii_hexdigit()) {
        return Err(malformed());
    }
    let fingerprint = u64::from_str_radix(fp_hex, 16).map_err(|_| malformed())?;
    let req: PlanRequest = request.parse()?;
    Ok((fingerprint, req))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::planner::offset::GreedyBySize;
    use crate::planner::shared::GreedyBySizeImproved;
    use crate::planner::{OffsetPlanner, SharedObjectPlanner};

    #[test]
    fn offset_roundtrip() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let loaded = offset_plan_from_str(&text, &recs, &PlanRequest::new()).unwrap();
        assert_eq!(loaded, plan);
    }

    #[test]
    fn checksum_detects_tampering() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let tampered = text.replacen("0 ", "1 ", 1);
        assert!(matches!(
            offset_plan_from_str(&tampered, &recs, &PlanRequest::new()),
            Err(LoadError::BadChecksum) | Err(LoadError::Malformed(_))
        ));
    }

    #[test]
    fn truncation_detected() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let cut = &text[..text.len() / 2];
        assert!(offset_plan_from_str(cut, &recs, &PlanRequest::new()).is_err());
    }

    #[test]
    fn stale_plan_rejected_on_model_change() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        // "model changed": same count, different sizes
        let mut changed = recs.clone();
        changed.records[2].size += 64;
        assert_eq!(
            offset_plan_from_str(&text, &changed, &PlanRequest::new()),
            Err(LoadError::RecordMismatch { record: 2, field: "size" })
        );
    }

    #[test]
    fn corrupted_checksum_line_rejected() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        // Flip one hex digit of the checksum itself (keep it valid hex).
        let pos = text.rfind("checksum ").unwrap() + "checksum ".len();
        let mut bytes = text.into_bytes();
        bytes[pos] = if bytes[pos] == b'0' { b'1' } else { b'0' };
        let corrupted = String::from_utf8(bytes).unwrap();
        assert_eq!(
            offset_plan_from_str(&corrupted, &recs, &PlanRequest::new()),
            Err(LoadError::BadChecksum)
        );
        // Non-hex garbage in the checksum is also a checksum error.
        let plan2 = GreedyBySize.plan(&recs);
        let mut garbled = offset_plan_to_string(&plan2, &recs, &PlanRequest::new());
        garbled.truncate(garbled.rfind("checksum ").unwrap());
        garbled.push_str("checksum zzzz\n");
        assert_eq!(
            offset_plan_from_str(&garbled, &recs, &PlanRequest::new()),
            Err(LoadError::BadChecksum)
        );
    }

    #[test]
    fn missing_checksum_is_truncation() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let cut = text.split("checksum").next().unwrap();
        assert_eq!(offset_plan_from_str(cut, &recs, &PlanRequest::new()), Err(LoadError::Truncated));
    }

    #[test]
    fn stale_plan_rejected_on_interval_change() {
        // Same sizes, shifted liveness: the loader must still refuse.
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let mut changed = recs.clone();
        changed.records[1].last_op += 1;
        assert_eq!(
            offset_plan_from_str(&text, &changed, &PlanRequest::new()),
            Err(LoadError::RecordMismatch { record: 1, field: "last_op" })
        );
    }

    #[test]
    fn fingerprint_tracks_planner_relevant_fields_only() {
        let a = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        let b = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        assert_eq!(records_fingerprint(&a), records_fingerprint(&b));
        let c = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 192)]);
        assert_ne!(records_fingerprint(&a), records_fingerprint(&c));
        let d = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 3, 128)]);
        assert_ne!(records_fingerprint(&a), records_fingerprint(&d));
    }

    /// Re-checksum a tampered body so only the *structural* defence can
    /// catch it — FNV-1a is not cryptographic and anyone can recompute it.
    fn rechecksum(body_and_sum: &str) -> String {
        let body = &body_and_sum[..body_and_sum.rfind("checksum ").unwrap()];
        let sum = fnv1a(body.as_bytes());
        format!("{body}checksum {sum:016x}\n")
    }

    #[test]
    fn dropped_record_line_rejected_even_with_consistent_checksum() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        // Drop record 3's line and recompute the checksum: without the
        // coverage check this half-loads with record 3 at offset 0.
        let dropped: String = text
            .lines()
            .filter(|l| !l.starts_with("3 "))
            .map(|l| format!("{l}\n"))
            .collect();
        assert_eq!(
            offset_plan_from_str(&rechecksum(&dropped), &recs, &PlanRequest::new()),
            Err(LoadError::RecordMismatch { record: 3, field: "missing" })
        );
    }

    #[test]
    fn duplicated_record_line_rejected_even_with_consistent_checksum() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let line3 = text.lines().find(|l| l.starts_with("3 ")).unwrap().to_string();
        let duplicated: String = text
            .lines()
            .flat_map(|l| {
                let mut v = vec![format!("{l}\n")];
                if l.starts_with("3 ") {
                    v.push(format!("{line3}\n"));
                }
                v
            })
            .collect();
        assert_eq!(
            offset_plan_from_str(&rechecksum(&duplicated), &recs, &PlanRequest::new()),
            Err(LoadError::RecordMismatch { record: 3, field: "duplicate" })
        );
    }

    #[test]
    fn huge_header_count_is_rejected_before_allocating() {
        // A crafted header count (checksum recomputed) must be a load
        // error, not a capacity-overflow abort in `vec![None; n]`.
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let bombed = text.replacen(
            &format!("offset {} ", recs.len()),
            &format!("offset {} ", usize::MAX),
            1,
        );
        assert_eq!(
            offset_plan_from_str(&rechecksum(&bombed), &recs, &PlanRequest::new()),
            Err(LoadError::RecordMismatch { record: usize::MAX, field: "count" })
        );
    }

    #[test]
    fn inflated_total_is_rejected() {
        // Feasibility only bounds the total from below; a tampered header
        // inflating it (checksum recomputed) must not poison the cache.
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let inflated = text.replacen(
            &format!(" {} natural\n", plan.total),
            &format!(" {} natural\n", recs.naive_total() + 1),
            1,
        );
        assert_ne!(inflated, text, "tampering must have hit the header");
        assert!(matches!(
            offset_plan_from_str(&rechecksum(&inflated), &recs, &PlanRequest::new()),
            Err(LoadError::Infeasible(_))
        ));
        // The exact naive bound itself is still legal (the Naive strategy).
        let naive_plan = crate::planner::offset::NaiveOffset.plan(&recs);
        let naive_text = offset_plan_to_string(&naive_plan, &recs, &PlanRequest::new());
        assert!(offset_plan_from_str(&naive_text, &recs, &PlanRequest::new()).is_ok());
    }

    #[test]
    fn resolved_prefix_fingerprint_tracks_resolution_and_sizes() {
        use crate::planner::dynamic::{DynamicRecord, DynamicRecords};
        let base = |sizes: [usize; 3]| {
            DynamicRecords::new(
                vec![
                    DynamicRecord {
                        record: crate::records::UsageRecord {
                            id: 0, tensor: None, first_op: 0, last_op: 2, size: sizes[0],
                        },
                        known_at: 0,
                    },
                    DynamicRecord {
                        record: crate::records::UsageRecord {
                            id: 1, tensor: None, first_op: 2, last_op: 3, size: sizes[1],
                        },
                        known_at: 1,
                    },
                    DynamicRecord {
                        record: crate::records::UsageRecord {
                            id: 2, tensor: None, first_op: 4, last_op: 5, size: sizes[2],
                        },
                        known_at: 3,
                    },
                ],
                6,
            )
        };
        let a = base([64, 128, 256]);
        // Decode steps between wave boundaries share the fingerprint...
        assert_eq!(
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(1)),
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(2)),
            "no wave resolves between ops 1 and 2"
        );
        // ...a newly-resolved wave changes it...
        assert_ne!(
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(1)),
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(3))
        );
        // ...and so does a different *value* for an already-resolved size,
        // while an unresolved size does not participate at all.
        let b = base([64, 999, 256]);
        assert_ne!(
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(1)),
            resolved_prefix_fingerprint(&b, DynamicMode::Resolved(1)),
            "resolved size differs"
        );
        let c = base([64, 128, 999]);
        assert_eq!(
            resolved_prefix_fingerprint(&a, DynamicMode::Resolved(1)),
            resolved_prefix_fingerprint(&c, DynamicMode::Resolved(1)),
            "unresolved tail sizes must not leak into the prefix fingerprint"
        );
        // With every wave resolved, all sizes participate.
        assert_ne!(
            resolved_prefix_fingerprint(&a, DynamicMode::FullyResolved),
            resolved_prefix_fingerprint(&c, DynamicMode::FullyResolved)
        );
    }

    #[test]
    fn plan_file_name_roundtrips() {
        use crate::planner::registry::OrderStrategy;
        for (fp, batch, strategy, order) in [
            (0u64, 1usize, "naive", OrderStrategy::Natural),
            (0xdeadbeefcafef00d, 8, "greedy-size", OrderStrategy::MemoryAware),
            (
                u64::MAX,
                64,
                "greedy-breadth",
                OrderStrategy::Annealed { seed: 42, budget: 100 },
            ),
            (1, 123, "strip-packing", OrderStrategy::Natural),
        ] {
            let req = PlanRequest::new()
                .with_strategy(strategy)
                .unwrap()
                .with_batch(batch)
                .with_order(order);
            let name = plan_file_name(fp, &req);
            assert_eq!(parse_plan_file_name(&name), Ok((fp, req)), "{name}");
            // Every quantized size class roundtrips too; f32 adds nothing.
            for dtype in crate::planner::Dtype::ALL {
                let qreq = req.with_dtype(dtype);
                let qname = plan_file_name(fp, &qreq);
                assert_eq!(parse_plan_file_name(&qname), Ok((fp, qreq)), "{qname}");
                if dtype == crate::planner::Dtype::F32 {
                    assert_eq!(qname, name, "f32 names stay byte-identical");
                }
            }
        }
        // An unknown dtype key in an otherwise-valid name is stale, not
        // malformed — forward compatibility for a newer build's plans.
        assert_eq!(
            parse_plan_file_name("0000000000000000-b1-naive@natural~i4.plan"),
            Err(ParseRequestError::UnknownDtype("i4".into()))
        );
        // Junk that must not parse: tmp files, truncated names, batch 0,
        // pre-bump v1 names without the @<order> segment, empty order.
        for bad in [
            "README.md",
            ".0000000000000000-b1-naive@natural.plan.tmp",
            "0000000000000000-b0-naive@natural.plan",
            "0000000000000000-b1-@natural.plan",
            "0000000000000000-b1-naive@.plan",
            "0000000000000000-b1-naive.plan",
            "xyz-b1-naive@natural.plan",
            "0000000000000000.plan",
        ] {
            assert!(
                matches!(parse_plan_file_name(bad), Err(ParseRequestError::Malformed(_))),
                "{bad}"
            );
        }
        // A registered grammar with an unregistered strategy is *stale*,
        // not malformed — warm starts count the two differently.
        assert_eq!(
            parse_plan_file_name("0000000000000000-b1-belady@natural.plan"),
            Err(ParseRequestError::UnknownStrategy("belady".into()))
        );
    }

    #[test]
    fn order_mismatch_is_rejected() {
        use crate::planner::registry::OrderStrategy;
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let annealed = PlanRequest::new()
            .with_order(OrderStrategy::Annealed { seed: 42, budget: 100 });
        let text = offset_plan_to_string(&plan, &recs, &annealed);
        // The matching expectation loads...
        assert_eq!(offset_plan_from_str(&text, &recs, &annealed).unwrap(), plan);
        // ...a different order (including the natural default) does not.
        assert_eq!(
            offset_plan_from_str(&text, &recs, &PlanRequest::new()),
            Err(LoadError::OrderMismatch {
                found: "annealed-s42-t100".into(),
                expected: "natural".into(),
            })
        );
        assert!(matches!(
            offset_plan_from_str(
                &text,
                &recs,
                &PlanRequest::new().with_order(OrderStrategy::MemoryAware)
            ),
            Err(LoadError::OrderMismatch { .. })
        ));
    }

    #[test]
    fn pre_bump_v1_text_is_rejected_by_version() {
        // Reconstruct the retired v1 layout (no order field) with a
        // consistent checksum: the loader must name the version, not guess
        // at the field layout.
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let v2 = offset_plan_to_string(&plan, &recs, &PlanRequest::new());
        let v1 = rechecksum(
            &v2.replacen("tensorarena-plan v2", "tensorarena-plan v1", 1)
                .replacen(&format!(" {} natural\n", plan.total), &format!(" {}\n", plan.total), 1),
        );
        assert_eq!(
            offset_plan_from_str(&v1, &recs, &PlanRequest::new()),
            Err(LoadError::UnsupportedVersion("v1".into()))
        );
    }

    #[test]
    fn shared_serialization_is_stable() {
        let recs = example_records();
        let plan = GreedyBySizeImproved.plan(&recs);
        let a = shared_plan_to_string(&plan, &recs);
        let b = shared_plan_to_string(&plan, &recs);
        assert_eq!(a, b);
        assert!(a.starts_with("tensorarena-plan v1 shared 8 3"));
        assert!(a.trim_end().ends_with(|c: char| c.is_ascii_hexdigit()));
    }
}
