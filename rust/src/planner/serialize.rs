//! Plan serialization: compute offline, ship with the model, load at serve
//! time (what TFLite does with its prepacked arena plans).
//!
//! Hand-rolled line format (the offline registry has no serde): versioned,
//! self-describing, whitespace-tokenized, with a trailing checksum so a
//! truncated file never half-loads.
//!
//! ```text
//! tensorarena-plan v1 offset <n> <total>
//! <record_id> <offset> <size> <first_op> <last_op>   # one per record
//! checksum <fnv1a of all prior lines>
//! ```
//!
//! The embedded `(size, first_op, last_op)` triples let the loader verify
//! the plan matches the records it is applied to — loading a stale plan
//! against a changed model fails loudly instead of corrupting tensors.

use super::{OffsetPlan, SharedObjectPlan};
use crate::records::UsageRecords;

/// FNV-1a over bytes (stable, dependency-free). Also the hash behind
/// [`records_fingerprint`] and therefore the plan cache's keys.
pub fn fnv1a(data: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// FNV-1a fingerprint of a record set — everything a planner consumes
/// (`num_ops` plus every `(first_op, last_op, size)` triple, in record
/// order). Two graphs with equal fingerprints get identical plans, which is
/// what lets `planner::cache::PlanCache` key on it.
pub fn records_fingerprint(records: &UsageRecords) -> u64 {
    let mut buf = Vec::with_capacity(8 + records.len() * 24);
    buf.extend_from_slice(&(records.num_ops as u64).to_le_bytes());
    for r in &records.records {
        buf.extend_from_slice(&(r.first_op as u64).to_le_bytes());
        buf.extend_from_slice(&(r.last_op as u64).to_le_bytes());
        buf.extend_from_slice(&(r.size as u64).to_le_bytes());
    }
    fnv1a(&buf)
}

/// Serialize an offset plan together with the records it plans.
pub fn offset_plan_to_string(plan: &OffsetPlan, records: &UsageRecords) -> String {
    let mut body = format!(
        "tensorarena-plan v1 offset {} {}\n",
        records.len(),
        plan.total
    );
    for r in &records.records {
        body.push_str(&format!(
            "{} {} {} {} {}\n",
            r.id, plan.offsets[r.id], r.size, r.first_op, r.last_op
        ));
    }
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Serialize a shared-objects plan.
pub fn shared_plan_to_string(plan: &SharedObjectPlan, records: &UsageRecords) -> String {
    let mut body = format!(
        "tensorarena-plan v1 shared {} {}\n",
        records.len(),
        plan.object_sizes.len()
    );
    body.push_str("objects");
    for s in &plan.object_sizes {
        body.push_str(&format!(" {s}"));
    }
    body.push('\n');
    for r in &records.records {
        body.push_str(&format!(
            "{} {} {} {} {}\n",
            r.id, plan.assignment[r.id], r.size, r.first_op, r.last_op
        ));
    }
    let sum = fnv1a(body.as_bytes());
    body.push_str(&format!("checksum {sum:016x}\n"));
    body
}

/// Errors while loading a plan.
#[derive(Debug, PartialEq, Eq)]
pub enum LoadError {
    BadHeader(String),
    BadChecksum,
    Truncated,
    Malformed(usize),
    /// The plan was produced for different records.
    RecordMismatch {
        record: usize,
        field: &'static str,
    },
    Infeasible(String),
}

impl std::fmt::Display for LoadError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoadError::BadHeader(h) => write!(f, "bad plan header: {h}"),
            LoadError::BadChecksum => write!(f, "plan checksum mismatch"),
            LoadError::Truncated => write!(f, "plan file truncated"),
            LoadError::Malformed(line) => write!(f, "malformed plan line {line}"),
            LoadError::RecordMismatch { record, field } => {
                write!(f, "plan does not match records: record {record}, field {field}")
            }
            LoadError::Infeasible(e) => write!(f, "loaded plan infeasible: {e}"),
        }
    }
}

impl std::error::Error for LoadError {}

fn split_checksum(text: &str) -> Result<(&str, u64), LoadError> {
    let body_end = text.rfind("checksum ").ok_or(LoadError::Truncated)?;
    let (body, tail) = text.split_at(body_end);
    let sum_hex = tail.trim_start_matches("checksum ").trim();
    let sum = u64::from_str_radix(sum_hex, 16).map_err(|_| LoadError::BadChecksum)?;
    Ok((body, sum))
}

/// Load and verify an offset plan against `records`.
pub fn offset_plan_from_str(text: &str, records: &UsageRecords) -> Result<OffsetPlan, LoadError> {
    let (body, sum) = split_checksum(text)?;
    if fnv1a(body.as_bytes()) != sum {
        return Err(LoadError::BadChecksum);
    }
    let mut lines = body.lines();
    let header = lines.next().ok_or(LoadError::Truncated)?;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() != 5 || h[0] != "tensorarena-plan" || h[1] != "v1" || h[2] != "offset" {
        return Err(LoadError::BadHeader(header.to_string()));
    }
    let n: usize = h[3].parse().map_err(|_| LoadError::BadHeader(header.into()))?;
    let total: usize = h[4].parse().map_err(|_| LoadError::BadHeader(header.into()))?;
    if n != records.len() {
        return Err(LoadError::RecordMismatch { record: n, field: "count" });
    }
    let mut offsets = vec![0usize; n];
    for (li, line) in lines.enumerate() {
        let f: Vec<usize> = line
            .split_whitespace()
            .map(|t| t.parse().map_err(|_| LoadError::Malformed(li + 2)))
            .collect::<Result<_, _>>()?;
        if f.len() != 5 {
            return Err(LoadError::Malformed(li + 2));
        }
        let (id, offset, size, first, last) = (f[0], f[1], f[2], f[3], f[4]);
        if id >= n {
            return Err(LoadError::Malformed(li + 2));
        }
        let r = &records.records[id];
        if r.size != size {
            return Err(LoadError::RecordMismatch { record: id, field: "size" });
        }
        if r.first_op != first {
            return Err(LoadError::RecordMismatch { record: id, field: "first_op" });
        }
        if r.last_op != last {
            return Err(LoadError::RecordMismatch { record: id, field: "last_op" });
        }
        offsets[id] = offset;
    }
    let plan = OffsetPlan { offsets, total };
    plan.validate(records)
        .map_err(|e| LoadError::Infeasible(e.to_string()))?;
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;
    use crate::planner::offset::GreedyBySize;
    use crate::planner::shared::GreedyBySizeImproved;
    use crate::planner::{OffsetPlanner, SharedObjectPlanner};

    #[test]
    fn offset_roundtrip() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        let loaded = offset_plan_from_str(&text, &recs).unwrap();
        assert_eq!(loaded, plan);
    }

    #[test]
    fn checksum_detects_tampering() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        let tampered = text.replacen("0 ", "1 ", 1);
        assert!(matches!(
            offset_plan_from_str(&tampered, &recs),
            Err(LoadError::BadChecksum) | Err(LoadError::Malformed(_))
        ));
    }

    #[test]
    fn truncation_detected() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        let cut = &text[..text.len() / 2];
        assert!(offset_plan_from_str(cut, &recs).is_err());
    }

    #[test]
    fn stale_plan_rejected_on_model_change() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        // "model changed": same count, different sizes
        let mut changed = recs.clone();
        changed.records[2].size += 64;
        assert_eq!(
            offset_plan_from_str(&text, &changed),
            Err(LoadError::RecordMismatch { record: 2, field: "size" })
        );
    }

    #[test]
    fn corrupted_checksum_line_rejected() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        // Flip one hex digit of the checksum itself (keep it valid hex).
        let pos = text.rfind("checksum ").unwrap() + "checksum ".len();
        let mut bytes = text.into_bytes();
        bytes[pos] = if bytes[pos] == b'0' { b'1' } else { b'0' };
        let corrupted = String::from_utf8(bytes).unwrap();
        assert_eq!(
            offset_plan_from_str(&corrupted, &recs),
            Err(LoadError::BadChecksum)
        );
        // Non-hex garbage in the checksum is also a checksum error.
        let plan2 = GreedyBySize.plan(&recs);
        let mut garbled = offset_plan_to_string(&plan2, &recs);
        garbled.truncate(garbled.rfind("checksum ").unwrap());
        garbled.push_str("checksum zzzz\n");
        assert_eq!(
            offset_plan_from_str(&garbled, &recs),
            Err(LoadError::BadChecksum)
        );
    }

    #[test]
    fn missing_checksum_is_truncation() {
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        let cut = text.split("checksum").next().unwrap();
        assert_eq!(offset_plan_from_str(cut, &recs), Err(LoadError::Truncated));
    }

    #[test]
    fn stale_plan_rejected_on_interval_change() {
        // Same sizes, shifted liveness: the loader must still refuse.
        let recs = example_records();
        let plan = GreedyBySize.plan(&recs);
        let text = offset_plan_to_string(&plan, &recs);
        let mut changed = recs.clone();
        changed.records[1].last_op += 1;
        assert_eq!(
            offset_plan_from_str(&text, &changed),
            Err(LoadError::RecordMismatch { record: 1, field: "last_op" })
        );
    }

    #[test]
    fn fingerprint_tracks_planner_relevant_fields_only() {
        let a = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        let b = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        assert_eq!(records_fingerprint(&a), records_fingerprint(&b));
        let c = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 192)]);
        assert_ne!(records_fingerprint(&a), records_fingerprint(&c));
        let d = crate::records::UsageRecords::from_triples(&[(0, 1, 64), (1, 3, 128)]);
        assert_ne!(records_fingerprint(&a), records_fingerprint(&d));
    }

    #[test]
    fn shared_serialization_is_stable() {
        let recs = example_records();
        let plan = GreedyBySizeImproved.plan(&recs);
        let a = shared_plan_to_string(&plan, &recs);
        let b = shared_plan_to_string(&plan, &recs);
        assert_eq!(a, b);
        assert!(a.starts_with("tensorarena-plan v1 shared 8 3"));
        assert!(a.trim_end().ends_with(|c: char| c.is_ascii_hexdigit()));
    }
}
