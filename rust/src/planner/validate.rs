//! Independent plan validation.
//!
//! Every planner's output is checked against the §3/§4/§5 feasibility rules
//! by code that shares nothing with the planners themselves (these
//! validators are deliberately the "obviously correct O(n²)" formulation).
//! The CPU executor in `crate::exec` provides a second, behavioural check.

use super::{OffsetPlan, SharedObjectPlan};
use crate::records::UsageRecords;
use std::fmt;

/// Why a plan is infeasible.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PlanError {
    /// Plan length does not match the record count.
    WrongArity {
        /// Records the plan should cover.
        expected: usize,
        /// Records it actually covers.
        got: usize,
    },
    /// A record is assigned to a shared object that does not exist.
    UnknownObject {
        /// Offending record id.
        record: usize,
        /// Out-of-range object index.
        object: usize,
    },
    /// A shared object is smaller than a tensor assigned to it.
    ObjectTooSmall {
        /// Offending record id.
        record: usize,
        /// Object index.
        object: usize,
        /// The object's declared size.
        object_size: usize,
        /// The tensor's (larger) size.
        tensor_size: usize,
    },
    /// Two tensors with intersecting usage intervals share a shared object.
    SharedConflict {
        /// First record id.
        a: usize,
        /// Second record id.
        b: usize,
        /// The shared object both were assigned to.
        object: usize,
    },
    /// Two tensors with intersecting usage intervals overlap in the arena.
    OffsetConflict {
        /// First record id.
        a: usize,
        /// Second record id.
        b: usize,
    },
    /// The declared arena size is smaller than an allocation's end.
    TotalTooSmall {
        /// Offending record id.
        record: usize,
        /// `offset + size` of the allocation.
        end: usize,
        /// The declared arena total.
        total: usize,
    },
}

impl fmt::Display for PlanError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PlanError::WrongArity { expected, got } => {
                write!(f, "plan covers {got} records, expected {expected}")
            }
            PlanError::UnknownObject { record, object } => {
                write!(f, "record {record} assigned to unknown object {object}")
            }
            PlanError::ObjectTooSmall {
                record,
                object,
                object_size,
                tensor_size,
            } => write!(
                f,
                "object {object} (size {object_size}) too small for record {record} (size {tensor_size})"
            ),
            PlanError::SharedConflict { a, b, object } => write!(
                f,
                "records {a} and {b} have intersecting usage intervals but share object {object}"
            ),
            PlanError::OffsetConflict { a, b } => write!(
                f,
                "records {a} and {b} have intersecting usage intervals and overlapping memory"
            ),
            PlanError::TotalTooSmall { record, end, total } => write!(
                f,
                "record {record} ends at offset {end} beyond declared total {total}"
            ),
        }
    }
}

impl std::error::Error for PlanError {}

/// Validate a Shared-Objects plan: arity, object existence, capacity, and
/// the §4 exclusivity rule ("no two tensors with intersecting usage
/// intervals can be assigned to the same shared object").
pub fn validate_shared(plan: &SharedObjectPlan, records: &UsageRecords) -> Result<(), PlanError> {
    if plan.assignment.len() != records.len() {
        return Err(PlanError::WrongArity {
            expected: records.len(),
            got: plan.assignment.len(),
        });
    }
    for r in &records.records {
        let obj = plan.assignment[r.id];
        if obj >= plan.object_sizes.len() {
            return Err(PlanError::UnknownObject { record: r.id, object: obj });
        }
        if plan.object_sizes[obj] < r.size {
            return Err(PlanError::ObjectTooSmall {
                record: r.id,
                object: obj,
                object_size: plan.object_sizes[obj],
                tensor_size: r.size,
            });
        }
    }
    for a in &records.records {
        for b in &records.records {
            if a.id < b.id && plan.assignment[a.id] == plan.assignment[b.id] && a.overlaps(b) {
                return Err(PlanError::SharedConflict {
                    a: a.id,
                    b: b.id,
                    object: plan.assignment[a.id],
                });
            }
        }
    }
    Ok(())
}

/// Validate an Offset plan: arity, declared total, and the §5 rule (tensors
/// with intersecting usage intervals must occupy disjoint byte ranges).
pub fn validate_offset(plan: &OffsetPlan, records: &UsageRecords) -> Result<(), PlanError> {
    if plan.offsets.len() != records.len() {
        return Err(PlanError::WrongArity {
            expected: records.len(),
            got: plan.offsets.len(),
        });
    }
    for r in &records.records {
        let end = plan.offsets[r.id] + r.size;
        if end > plan.total {
            return Err(PlanError::TotalTooSmall { record: r.id, end, total: plan.total });
        }
    }
    for a in &records.records {
        for b in &records.records {
            if a.id < b.id && a.overlaps(b) {
                let (oa, ob) = (plan.offsets[a.id], plan.offsets[b.id]);
                if oa < ob + b.size && ob < oa + a.size {
                    return Err(PlanError::OffsetConflict { a: a.id, b: b.id });
                }
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    fn recs() -> UsageRecords {
        UsageRecords::from_triples(&[(0, 2, 10), (1, 3, 20), (4, 5, 10)])
    }

    #[test]
    fn accepts_feasible_shared_plan() {
        let r = recs();
        // records 0 and 2 do not overlap -> may share object 0
        let p = SharedObjectPlan {
            object_sizes: vec![10, 20],
            assignment: vec![0, 1, 0],
        };
        assert!(validate_shared(&p, &r).is_ok());
    }

    #[test]
    fn rejects_shared_conflict() {
        let r = recs();
        let p = SharedObjectPlan {
            object_sizes: vec![20],
            assignment: vec![0, 0, 0],
        };
        assert_eq!(
            validate_shared(&p, &r),
            Err(PlanError::SharedConflict { a: 0, b: 1, object: 0 })
        );
    }

    #[test]
    fn rejects_undersized_object() {
        let r = recs();
        let p = SharedObjectPlan {
            object_sizes: vec![10, 10],
            assignment: vec![0, 1, 0],
        };
        assert!(matches!(
            validate_shared(&p, &r),
            Err(PlanError::ObjectTooSmall { record: 1, .. })
        ));
    }

    #[test]
    fn rejects_wrong_arity() {
        let r = recs();
        let p = SharedObjectPlan { object_sizes: vec![], assignment: vec![] };
        assert!(matches!(validate_shared(&p, &r), Err(PlanError::WrongArity { .. })));
    }

    #[test]
    fn accepts_feasible_offset_plan() {
        let r = recs();
        let p = OffsetPlan { offsets: vec![0, 10, 0], total: 30 };
        assert!(validate_offset(&p, &r).is_ok());
    }

    #[test]
    fn rejects_offset_conflict() {
        let r = recs();
        // records 0 and 1 overlap in time and in memory
        let p = OffsetPlan { offsets: vec![0, 5, 0], total: 30 };
        assert_eq!(
            validate_offset(&p, &r),
            Err(PlanError::OffsetConflict { a: 0, b: 1 })
        );
    }

    #[test]
    fn rejects_total_too_small() {
        let r = recs();
        let p = OffsetPlan { offsets: vec![0, 10, 0], total: 20 };
        assert!(matches!(
            validate_offset(&p, &r),
            Err(PlanError::TotalTooSmall { record: 1, .. })
        ));
    }
}
