//! `PlanService`: the single path from graph to planned memory.
//!
//! One shared handle bundles the three pieces every layer needs:
//! the strategy [`registry`](super::registry) (which strategies exist), the
//! batch-aware [`PlanCache`] (plan once per `(model, batch, strategy,
//! order)`), and the [`ArenaPool`] (recycle arena buffers instead of
//! reallocating them per executor). The coordinator's engines, the CPU
//! executor, the `serve` CLI, and the benches all take an
//! `Arc<PlanService>` so their plans and arenas — and the hit/reuse
//! counters that prove the reuse — come from one place.
//!
//! Execution order is a first-class plan dimension here:
//! [`PlanService::plan_graph`] applies the requested
//! [`OrderStrategy`](super::registry::OrderStrategy) — reorder, validate,
//! *then* extract records — so the annealed orders of
//! [`order`](super::order) reach the serving hot path, and every ordered
//! plan lands in an order-keyed cache slot.
//!
//! Dynamic shapes (§7) ride the same path:
//! [`PlanService::plan_graph_dynamic`] overlays a decode-tail profile on
//! the ordered records and plans the multi-pass plan through the
//! resolved-prefix-keyed dynamic cache slots, so a wave-aware engine's
//! decode-step re-plans ([`PlanService::plan_dynamic_resolved`]) and its
//! budget admission ([`PlanService::max_servable_batch_dynamic`], resolved
//! under the worst-wave peak) are amortized exactly like static plans.

use super::cache::{PersistReport, PlanCache, PlanServiceError, WarmStartReport};
use super::dynamic::{DynamicRecords, MultiPassPlan};
use super::order::{self, AppliedOrder};
use super::registry::OrderStrategy;
use super::{registry, OffsetPlan};
use crate::arena::ArenaPool;
use crate::graph::Graph;
use crate::records::UsageRecords;
use std::path::Path;
use std::sync::Arc;

/// Shared planning façade: registry + plan cache + arena pool.
///
/// # Example
///
/// Every engine sharing the handle plans each `(model, batch, strategy,
/// order)` exactly once:
///
/// ```
/// use tensorarena::models;
/// use tensorarena::planner::PlanService;
/// use tensorarena::records::UsageRecords;
///
/// let service = PlanService::shared();
/// let records = UsageRecords::from_graph(&models::blazeface());
/// let a = service.plan_records(&records, 2, None).unwrap();
/// let b = service.plan_records(&records, 2, None).unwrap();
/// assert!(std::sync::Arc::ptr_eq(&a, &b)); // planned once, shared
/// assert_eq!(service.stats().cache_misses, 1);
/// assert_eq!(service.stats().cache_hits, 1);
/// ```
pub struct PlanService {
    cache: PlanCache,
    pool: Arc<ArenaPool>,
    default_strategy: &'static str,
}

/// Point-in-time counters, the serving-visible proof of plan/arena reuse.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanServiceStats {
    /// Plan-cache hits (a planner invocation avoided).
    pub cache_hits: u64,
    /// Plan-cache misses (a planner actually ran).
    pub cache_misses: u64,
    /// Arena buffers recycled from the pool.
    pub pool_reused: u64,
    /// Arena buffers freshly allocated.
    pub pool_allocated: u64,
    /// Plans seeded from a plan directory at warm start.
    pub warm_loaded: u64,
    /// Plan-directory files skipped at warm start (corrupt or stale).
    pub warm_skipped: u64,
    /// Dynamic (§7 multi-pass) plan-cache hits — decode-step re-plans
    /// answered with zero planner invocations.
    pub dynamic_hits: u64,
    /// Dynamic plan-cache misses (multi-pass planner invocations).
    pub dynamic_misses: u64,
}

impl PlanServiceStats {
    /// Cache hits / lookups, or 0.0 before the first lookup.
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Default for PlanService {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanService {
    /// The §6-recommended default offset strategy.
    pub const DEFAULT_STRATEGY: &'static str = "greedy-size";

    /// Service with the default strategy and a fresh cache/pool.
    pub fn new() -> Self {
        Self::with_default_strategy(Self::DEFAULT_STRATEGY).expect("default strategy registered")
    }

    /// Service defaulting to `strategy` (any registry key or display name).
    pub fn with_default_strategy(strategy: &str) -> Result<Self, PlanServiceError> {
        let key = registry::offset_key(strategy)
            .ok_or_else(|| PlanServiceError::UnknownStrategy(strategy.to_string()))?;
        Ok(PlanService {
            cache: PlanCache::new(),
            pool: Arc::new(ArenaPool::new()),
            default_strategy: key,
        })
    }

    /// The usual way to construct: one shared handle for all engines.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Canonical key of the default strategy.
    pub fn default_strategy(&self) -> &'static str {
        self.default_strategy
    }

    /// The underlying plan cache.
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// The shared arena pool.
    pub fn pool(&self) -> &Arc<ArenaPool> {
        &self.pool
    }

    /// Plan `records` (batch-1 form) scaled to `batch` under `strategy`
    /// (`None` = the service default), through the cache, for the natural
    /// execution order.
    pub fn plan_records(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: Option<&str>,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        self.plan_records_ordered(records, batch, strategy, OrderStrategy::Natural)
    }

    /// Plan `records` (batch-1 form, extracted under `order`) scaled to
    /// `batch` under `strategy`, through the order-keyed cache slot.
    pub fn plan_records_ordered(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        self.cache.get_or_plan_ordered(
            records,
            batch,
            strategy.unwrap_or(self.default_strategy),
            order,
        )
    }

    /// Apply `order` to `graph` — reorder ops, validate the order, report
    /// the §5.1 breadth movement — without planning anything. Natural is
    /// the identity. See [`order::apply_order`].
    pub fn apply_order(&self, graph: &Graph, order: OrderStrategy) -> (Graph, AppliedOrder) {
        order::apply_order(graph, order)
    }

    /// Apply `order` to `graph`, extract usage records from the reordered
    /// graph, and plan them at `batch`. The returned records are the
    /// *ordered* records — the ones every later cache lookup, budget query,
    /// and warm start for this serving configuration must use — and the
    /// [`AppliedOrder`] receipt carries the breadth delta `ArenaStats`
    /// reports.
    pub fn plan_graph(
        &self,
        graph: &Graph,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<(UsageRecords, Arc<OffsetPlan>, AppliedOrder), PlanServiceError> {
        let (ordered, applied) = self.apply_order(graph, order);
        let records = UsageRecords::from_graph(&ordered);
        let plan = self.plan_records_ordered(&records, batch, strategy, order)?;
        Ok((records, plan, applied))
    }

    /// The complete §7 multi-pass plan for `dynamic` (batch-1 records of
    /// the order-applied graph) scaled to `batch`, through the dynamic
    /// cache slot; see [`PlanCache::get_or_plan_dynamic`]. The plan's
    /// [`MultiPassPlan::peak`] is the worst-wave peak the wave-aware
    /// executor sizes its pooled arena from.
    pub fn plan_dynamic(
        &self,
        dynamic: &DynamicRecords,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        self.cache.get_or_plan_dynamic(
            dynamic,
            batch,
            strategy.unwrap_or(self.default_strategy),
            order,
        )
    }

    /// The §7 prefix plan of the waves resolved once op `resolved_through`
    /// has executed — the decode-step re-plan. Repeats with an unchanged
    /// resolved prefix are cache hits with zero planner invocations; see
    /// [`PlanCache::get_or_plan_dynamic_resolved`].
    pub fn plan_dynamic_resolved(
        &self,
        dynamic: &DynamicRecords,
        resolved_through: usize,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        self.cache.get_or_plan_dynamic_resolved(
            dynamic,
            resolved_through,
            batch,
            strategy.unwrap_or(self.default_strategy),
            order,
        )
    }

    /// Apply `order` to `graph`, extract its records, overlay the
    /// decode-tail dynamic profile starting at `decode_from` (see
    /// [`DynamicRecords::decode_tail`]), and plan the complete multi-pass
    /// plan at `batch` — the dynamic analogue of [`Self::plan_graph`].
    /// This is the one-call *library* path; `serve --dynamic` and the
    /// wave-aware engine perform the same sequence inline because they
    /// also need the intermediate records/ordered graph, so any change to
    /// the overlay here must be mirrored there (the cache keys must
    /// agree).
    pub fn plan_graph_dynamic(
        &self,
        graph: &Graph,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
        decode_from: usize,
    ) -> Result<(DynamicRecords, Arc<MultiPassPlan>, AppliedOrder), PlanServiceError> {
        let (ordered, applied) = self.apply_order(graph, order);
        let records = UsageRecords::from_graph(&ordered);
        let dynamic = DynamicRecords::decode_tail(&records, decode_from);
        let plan = self.plan_dynamic(&dynamic, batch, strategy, order)?;
        Ok((dynamic, plan, applied))
    }

    /// Largest batch whose **worst-wave** multi-pass peak fits
    /// `budget_bytes` — what budget admission for a dynamic-shape engine
    /// resolves; see [`PlanCache::max_servable_batch_dynamic`].
    pub fn max_servable_batch_dynamic(
        &self,
        dynamic: &DynamicRecords,
        budget_bytes: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        self.cache.max_servable_batch_dynamic(
            dynamic,
            strategy.unwrap_or(self.default_strategy),
            budget_bytes,
            order,
        )
    }

    /// Largest batch whose planned footprint fits `budget_bytes`, for the
    /// natural execution order; see [`PlanCache::max_servable_batch`].
    pub fn max_servable_batch(
        &self,
        records: &UsageRecords,
        budget_bytes: usize,
        strategy: Option<&str>,
    ) -> Result<usize, PlanServiceError> {
        self.max_servable_batch_ordered(records, budget_bytes, strategy, OrderStrategy::Natural)
    }

    /// Largest batch whose planned footprint fits `budget_bytes`, resolved
    /// under `order` (the records must be the reordered graph's); see
    /// [`PlanCache::max_servable_batch_ordered`].
    pub fn max_servable_batch_ordered(
        &self,
        records: &UsageRecords,
        budget_bytes: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        self.cache.max_servable_batch_ordered(
            records,
            strategy.unwrap_or(self.default_strategy),
            budget_bytes,
            order,
        )
    }

    /// Seed the plan cache from a plan directory (see
    /// [`PlanCache::warm_start`]), for the natural execution order: a
    /// restarted server re-plans nothing it has already planned.
    pub fn warm_start(
        &self,
        dir: &Path,
        records: &UsageRecords,
    ) -> std::io::Result<WarmStartReport> {
        self.cache.warm_start(dir, records)
    }

    /// Seed the plan cache from a plan directory for an order-keyed serving
    /// configuration (see [`PlanCache::warm_start_ordered`]): only files
    /// written under the same canonical order key are loaded; stale-order
    /// files are skipped and counted.
    pub fn warm_start_ordered(
        &self,
        dir: &Path,
        records: &UsageRecords,
        order: OrderStrategy,
    ) -> std::io::Result<WarmStartReport> {
        self.cache.warm_start_ordered(dir, records, order)
    }

    /// Persist every resident plan into `dir` (see
    /// [`PlanCache::persist_dir`]).
    pub fn persist_dir(&self, dir: &Path) -> std::io::Result<PersistReport> {
        self.cache.persist_dir(dir)
    }

    /// Current reuse counters.
    pub fn stats(&self) -> PlanServiceStats {
        PlanServiceStats {
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            pool_reused: self.pool.reused(),
            pool_allocated: self.pool.allocated(),
            warm_loaded: self.cache.warm_loaded(),
            warm_skipped: self.cache.warm_skipped(),
            dynamic_hits: self.cache.dynamic_hits(),
            dynamic_misses: self.cache.dynamic_misses(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn default_strategy_is_registered_and_used() {
        let svc = PlanService::new();
        assert_eq!(svc.default_strategy(), "greedy-size");
        let recs = example_records();
        let a = svc.plan_records(&recs, 1, None).unwrap();
        let b = svc.plan_records(&recs, 1, Some("greedy-size")).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let st = svc.stats();
        assert_eq!((st.cache_misses, st.cache_hits), (1, 1));
        assert!((st.cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn unknown_default_strategy_rejected() {
        assert!(PlanService::with_default_strategy("belady").is_err());
        assert!(PlanService::with_default_strategy("Greedy by Breadth").is_ok());
    }

    #[test]
    fn plan_graph_plans_the_extracted_records() {
        let svc = PlanService::new();
        let g = crate::models::example_net();
        let (records, plan, applied) = svc
            .plan_graph(&g, 1, None, OrderStrategy::Natural)
            .unwrap();
        assert_eq!(plan.offsets.len(), records.len());
        assert_eq!(applied.breadth_delta(), 0);
        plan.validate(&records).unwrap();
    }

    #[test]
    fn plan_graph_dynamic_amortizes_decode_step_replans() {
        let svc = PlanService::new();
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let (dynamic, plan, applied) = svc
            .plan_graph_dynamic(&g, 1, None, OrderStrategy::Natural, decode_from)
            .unwrap();
        assert!(plan.is_complete());
        assert!(plan.passes >= 2, "a decode tail must produce multiple waves");
        assert_eq!(applied.breadth_delta(), 0);
        // The complete plan is feasible for the final sizes, and the peak
        // equals the monotone growth's high-water mark.
        plan.offset_plan().unwrap().validate(&dynamic.final_records()).unwrap();
        assert_eq!(plan.peak, *plan.growth.last().unwrap());
        // A decode loop over every op: the first sequence plans once per
        // distinct resolved prefix, the second plans nothing.
        for step in 0..dynamic.num_ops {
            svc.plan_dynamic_resolved(&dynamic, step, 1, None, OrderStrategy::Natural)
                .unwrap();
        }
        let misses = svc.stats().dynamic_misses;
        for step in 0..dynamic.num_ops {
            svc.plan_dynamic_resolved(&dynamic, step, 1, None, OrderStrategy::Natural)
                .unwrap();
        }
        assert_eq!(
            svc.stats().dynamic_misses,
            misses,
            "a repeated decode pass must perform zero planner invocations"
        );
    }

    #[test]
    fn plan_graph_applies_the_order_before_record_extraction() {
        let svc = PlanService::new();
        let g = crate::models::blazeface();
        let order = OrderStrategy::Annealed { seed: 3, budget: 20 };
        let (records, plan, applied) = svc.plan_graph(&g, 1, None, order).unwrap();
        // The plan is feasible for the *ordered* records, and the reported
        // breadth never regresses the natural order (annealing invariant).
        plan.validate(&records).unwrap();
        assert!(applied.order_breadth <= applied.natural_breadth);
        assert_eq!(applied.key(), order.key());
        // Re-planning the same configuration is an order-keyed cache hit.
        let _ = svc.plan_graph(&g, 1, None, order).unwrap();
        let st = svc.stats();
        assert_eq!((st.cache_misses, st.cache_hits), (1, 1));
        // Budget queries resolve under the same order: the cap's plan fits,
        // the next batch's does not.
        let budget = 2 * plan.total;
        let cap = svc
            .max_servable_batch_ordered(&records, budget, None, order)
            .unwrap();
        assert!(cap >= 1);
        let at_cap = svc
            .plan_records_ordered(&records, cap, None, order)
            .unwrap()
            .total;
        let above = svc
            .plan_records_ordered(&records, cap + 1, None, order)
            .unwrap()
            .total;
        assert!(at_cap <= budget && above > budget);
    }
}
