//! `PlanService`: the single path from graph to planned memory.
//!
//! One shared handle bundles the three pieces every layer needs:
//! the strategy [`registry`](super::registry) (which strategies exist), the
//! [`PlanCache`] (plan once per `(records fingerprint,
//! [`PlanRequest`])`), and the [`ArenaPool`] (recycle arena buffers
//! instead of reallocating them per executor). The coordinator's engines,
//! the CPU executor, the `serve` CLI, and the benches all take an
//! `Arc<PlanService>` so their plans and arenas — and the hit/reuse
//! counters that prove the reuse — come from one place.
//!
//! Every entry point takes a [`PlanRequest`]: strategy, execution order,
//! batch, and §7 dynamic resolution state travel as one typed value
//! instead of positional arguments and method suffixes. Start from
//! [`PlanService::request`] (seeded with the service's default strategy)
//! and refine with the builder:
//!
//! * [`PlanService::plan`] / [`PlanService::plan_graph`] — static plans
//!   (the graph variant applies the request's order *before* record
//!   extraction, so annealed orders reach the serving hot path and every
//!   ordered plan lands in an order-keyed cache slot);
//! * [`PlanService::plan_dynamic`] / [`PlanService::plan_graph_dynamic`] —
//!   §7 multi-pass plans through the resolved-prefix-keyed dynamic slots,
//!   so a wave-aware engine's decode-step re-plans
//!   ([`DynamicMode::Resolved`]) are amortized exactly like static plans;
//! * [`PlanService::max_servable_batch`] /
//!   [`PlanService::max_servable_batch_dynamic`] — budget admission
//!   (dynamic admission resolves under the worst-wave peak);
//! * [`PlanService::warm_start`] / [`PlanService::persist_dir`] — the plan
//!   directory, whose file names are the request's `Display` grammar.

use super::cache::{PersistReport, PlanCache, PlanServiceError, WarmStartReport};
use super::dynamic::{DynamicRecords, MultiPassPlan};
use super::order::{self, AppliedOrder};
use super::registry::OrderStrategy;
use super::request::{DynamicMode, PlanRequest};
use super::{registry, OffsetPlan};
use crate::arena::ArenaPool;
use crate::graph::Graph;
use crate::records::UsageRecords;
use std::path::Path;
use std::sync::Arc;

/// Shared planning façade: registry + plan cache + arena pool.
///
/// # Example
///
/// Every engine sharing the handle plans each [`PlanRequest`] exactly
/// once:
///
/// ```
/// use tensorarena::models;
/// use tensorarena::planner::PlanService;
/// use tensorarena::records::UsageRecords;
///
/// let service = PlanService::shared();
/// let records = UsageRecords::from_graph(&models::blazeface());
/// let req = service.request().with_batch(2);
/// let a = service.plan(&records, &req).unwrap();
/// let b = service.plan(&records, &req).unwrap();
/// assert!(std::sync::Arc::ptr_eq(&a, &b)); // planned once, shared
/// assert_eq!(service.stats().cache_misses, 1);
/// assert_eq!(service.stats().cache_hits, 1);
/// ```
pub struct PlanService {
    cache: PlanCache,
    pool: Arc<ArenaPool>,
    default_strategy: &'static str,
}

/// Point-in-time counters, the serving-visible proof of plan/arena reuse.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanServiceStats {
    /// Plan-cache hits (a planner invocation avoided).
    pub cache_hits: u64,
    /// Plan-cache misses (a planner actually ran).
    pub cache_misses: u64,
    /// Arena buffers recycled from the pool.
    pub pool_reused: u64,
    /// Arena buffers freshly allocated.
    pub pool_allocated: u64,
    /// Plans seeded from a plan directory at warm start.
    pub warm_loaded: u64,
    /// Plan-directory files skipped at warm start (corrupt or stale).
    pub warm_skipped: u64,
    /// Dynamic (§7 multi-pass) plan-cache hits — decode-step re-plans
    /// answered with zero planner invocations.
    pub dynamic_hits: u64,
    /// Dynamic plan-cache misses (multi-pass planner invocations).
    pub dynamic_misses: u64,
    /// Arena buffers dropped at release because their size class was at
    /// the pool's retention cap (pool churn, invisible before this).
    pub pool_dropped: u64,
    /// Buffers evicted from the pool's resident shelves into the spill
    /// tier (zero with no tier configured).
    pub spill_evictions: u64,
    /// Buffers demand-reloaded out of the spill tier on acquire misses.
    pub spill_reloads: u64,
    /// Raw bytes of everything evicted so far (before compression).
    pub spill_bytes_before: u64,
    /// Stored bytes of everything evicted so far (after compression).
    pub spill_bytes_after: u64,
    /// 99th-percentile spill reload stall, microseconds.
    pub spill_stall_p99_us: u64,
}

impl PlanServiceStats {
    /// Cache hits / lookups, or 0.0 before the first lookup (never `NaN`).
    pub fn cache_hit_rate(&self) -> f64 {
        let total = self.cache_hits + self.cache_misses;
        if total == 0 {
            0.0
        } else {
            self.cache_hits as f64 / total as f64
        }
    }
}

impl Default for PlanService {
    fn default() -> Self {
        Self::new()
    }
}

impl PlanService {
    /// The §6-recommended default offset strategy (=
    /// [`PlanRequest::DEFAULT_STRATEGY`]).
    pub const DEFAULT_STRATEGY: &'static str = PlanRequest::DEFAULT_STRATEGY;

    /// Service with the default strategy and a fresh cache/pool.
    pub fn new() -> Self {
        Self::with_default_strategy(Self::DEFAULT_STRATEGY).expect("default strategy registered")
    }

    /// Service defaulting to `strategy` (any registry key or display name).
    pub fn with_default_strategy(strategy: &str) -> Result<Self, PlanServiceError> {
        let key = registry::offset_key(strategy)
            .ok_or_else(|| PlanServiceError::UnknownStrategy(strategy.to_string()))?;
        Ok(PlanService {
            cache: PlanCache::new(),
            pool: Arc::new(ArenaPool::new()),
            default_strategy: key,
        })
    }

    /// The usual way to construct: one shared handle for all engines.
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// Canonical key of the default strategy.
    pub fn default_strategy(&self) -> &'static str {
        self.default_strategy
    }

    /// A batch-1 static [`PlanRequest`] for the service's default strategy
    /// under the natural order — the starting point for every builder
    /// chain against this service.
    pub fn request(&self) -> PlanRequest {
        PlanRequest::new().with_strategy_key(self.default_strategy)
    }

    /// Build a request from an optional strategy name (`None` = the
    /// service default) — what the deprecated positional-argument shims
    /// funnel through.
    fn request_for(&self, strategy: Option<&str>) -> Result<PlanRequest, PlanServiceError> {
        match strategy {
            None => Ok(self.request()),
            Some(s) => self.request().with_strategy(s),
        }
    }

    /// The underlying plan cache.
    pub fn cache(&self) -> &PlanCache {
        &self.cache
    }

    /// The shared arena pool.
    pub fn pool(&self) -> &Arc<ArenaPool> {
        &self.pool
    }

    /// The static plan `req` identifies for `records` (batch-1 form; for a
    /// non-natural order, the records of the graph reordered under that
    /// order), through the cache. See [`PlanCache::get_or_plan`].
    pub fn plan(
        &self,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        self.cache.get_or_plan(records, req)
    }

    /// [`Self::plan`] with untyped `(batch, strategy)` arguments, for the
    /// natural execution order.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call plan")]
    pub fn plan_records(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: Option<&str>,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let req = self.request_for(strategy)?.with_batch(batch);
        self.plan(records, &req)
    }

    /// [`Self::plan`] with untyped `(batch, strategy, order)` arguments.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call plan")]
    pub fn plan_records_ordered(
        &self,
        records: &UsageRecords,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<Arc<OffsetPlan>, PlanServiceError> {
        let req = self.request_for(strategy)?.with_batch(batch).with_order(order);
        self.plan(records, &req)
    }

    /// Apply `order` to `graph` — reorder ops, validate the order, report
    /// the §5.1 breadth movement — without planning anything. Natural is
    /// the identity. See [`order::apply_order`].
    pub fn apply_order(&self, graph: &Graph, order: OrderStrategy) -> (Graph, AppliedOrder) {
        order::apply_order(graph, order)
    }

    /// Apply the request's order to `graph`, extract usage records from
    /// the reordered graph, and plan them. The returned records are the
    /// *ordered* records — the ones every later cache lookup, budget
    /// query, and warm start for this serving configuration must use — and
    /// the [`AppliedOrder`] receipt carries the breadth delta `ArenaStats`
    /// reports.
    pub fn plan_graph(
        &self,
        graph: &Graph,
        req: &PlanRequest,
    ) -> Result<(UsageRecords, Arc<OffsetPlan>, AppliedOrder), PlanServiceError> {
        let (ordered, applied) = self.apply_order(graph, req.order());
        let records = UsageRecords::from_graph(&ordered);
        let plan = self.plan(&records, req)?;
        Ok((records, plan, applied))
    }

    /// The §7 multi-pass plan `req` identifies for `dynamic` (batch-1
    /// records of the order-applied graph), through the dynamic cache
    /// slot; see [`PlanCache::get_or_plan_dynamic`]. With
    /// [`DynamicMode::FullyResolved`] this is the complete plan whose
    /// [`MultiPassPlan::peak`] is the worst-wave peak the wave-aware
    /// executor sizes its pooled arena from; with
    /// [`DynamicMode::Resolved`]`(op)` it is the decode-step prefix plan —
    /// repeats with an unchanged resolved prefix are cache hits with zero
    /// planner invocations.
    pub fn plan_dynamic(
        &self,
        dynamic: &DynamicRecords,
        req: &PlanRequest,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        self.cache.get_or_plan_dynamic(dynamic, req)
    }

    /// [`Self::plan_dynamic`] with an untyped `resolved_through` op index
    /// (`usize::MAX` meaning fully resolved).
    #[deprecated(
        since = "0.3.0",
        note = "build a PlanRequest with a DynamicMode and call plan_dynamic"
    )]
    pub fn plan_dynamic_resolved(
        &self,
        dynamic: &DynamicRecords,
        resolved_through: usize,
        batch: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<Arc<MultiPassPlan>, PlanServiceError> {
        let req = self
            .request_for(strategy)?
            .with_batch(batch)
            .with_order(order)
            .with_dynamic(DynamicMode::from_resolved_through(resolved_through));
        self.plan_dynamic(dynamic, &req)
    }

    /// Apply the request's order to `graph`, extract its records, overlay
    /// the decode-tail dynamic profile starting at `decode_from` (see
    /// [`DynamicRecords::decode_tail`]), and plan the complete multi-pass
    /// plan — the dynamic analogue of [`Self::plan_graph`] (the request's
    /// own [`DynamicMode`] is overridden with
    /// [`DynamicMode::FullyResolved`]: this entry point exists to produce
    /// the complete plan). This is the one-call *library* path; `serve
    /// --dynamic` and the wave-aware engine perform the same sequence
    /// inline because they also need the intermediate records/ordered
    /// graph, so any change to the overlay here must be mirrored there
    /// (the cache keys must agree).
    pub fn plan_graph_dynamic(
        &self,
        graph: &Graph,
        req: &PlanRequest,
        decode_from: usize,
    ) -> Result<(DynamicRecords, Arc<MultiPassPlan>, AppliedOrder), PlanServiceError> {
        let (ordered, applied) = self.apply_order(graph, req.order());
        let records = UsageRecords::from_graph(&ordered);
        let dynamic = DynamicRecords::decode_tail(&records, decode_from);
        let plan =
            self.plan_dynamic(&dynamic, &req.with_dynamic(DynamicMode::FullyResolved))?;
        Ok((dynamic, plan, applied))
    }

    /// Largest batch whose **worst-wave** multi-pass peak fits
    /// `budget_bytes` — what budget admission for a dynamic-shape engine
    /// resolves; see [`PlanCache::max_servable_batch_dynamic`]. The
    /// request's batch and dynamic mode are immaterial (every probe plans
    /// the complete plan at the probed batch).
    pub fn max_servable_batch_dynamic(
        &self,
        dynamic: &DynamicRecords,
        req: &PlanRequest,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        self.cache.max_servable_batch_dynamic(dynamic, req, budget_bytes)
    }

    /// Largest batch whose planned footprint under the request's strategy
    /// and order fits `budget_bytes` (`records` must be the reordered
    /// graph's records for a non-natural order); see
    /// [`PlanCache::max_servable_batch`]. The request's batch is
    /// immaterial — the query searches over batches.
    pub fn max_servable_batch(
        &self,
        records: &UsageRecords,
        req: &PlanRequest,
        budget_bytes: usize,
    ) -> Result<usize, PlanServiceError> {
        self.cache.max_servable_batch(records, req, budget_bytes)
    }

    /// [`Self::max_servable_batch`] with untyped `(strategy, order)`
    /// arguments.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call max_servable_batch")]
    pub fn max_servable_batch_ordered(
        &self,
        records: &UsageRecords,
        budget_bytes: usize,
        strategy: Option<&str>,
        order: OrderStrategy,
    ) -> Result<usize, PlanServiceError> {
        let req = self.request_for(strategy)?.with_order(order);
        self.max_servable_batch(records, &req, budget_bytes)
    }

    /// Seed the plan cache from a plan directory (see
    /// [`PlanCache::warm_start`]): only files written under the request's
    /// execution order are loaded (stale-order files are skipped and
    /// counted); every `(batch, strategy)` in the directory is seeded, so
    /// a restarted server re-plans nothing it has already planned.
    pub fn warm_start(
        &self,
        dir: &Path,
        records: &UsageRecords,
        req: &PlanRequest,
    ) -> std::io::Result<WarmStartReport> {
        self.cache.warm_start(dir, records, req)
    }

    /// [`Self::warm_start`] with an untyped order.
    #[deprecated(since = "0.3.0", note = "build a PlanRequest and call warm_start")]
    pub fn warm_start_ordered(
        &self,
        dir: &Path,
        records: &UsageRecords,
        order: OrderStrategy,
    ) -> std::io::Result<WarmStartReport> {
        self.warm_start(dir, records, &self.request().with_order(order))
    }

    /// Persist every resident plan into `dir` (see
    /// [`PlanCache::persist_dir`]).
    pub fn persist_dir(&self, dir: &Path) -> std::io::Result<PersistReport> {
        self.cache.persist_dir(dir)
    }

    /// Current reuse counters.
    pub fn stats(&self) -> PlanServiceStats {
        let spill = self.pool.spill_tier().map(|t| t.stats()).unwrap_or_default();
        PlanServiceStats {
            cache_hits: self.cache.hits(),
            cache_misses: self.cache.misses(),
            pool_reused: self.pool.reused(),
            pool_allocated: self.pool.allocated(),
            warm_loaded: self.cache.warm_loaded(),
            warm_skipped: self.cache.warm_skipped(),
            dynamic_hits: self.cache.dynamic_hits(),
            dynamic_misses: self.cache.dynamic_misses(),
            pool_dropped: self.pool.dropped(),
            spill_evictions: spill.evictions,
            spill_reloads: spill.reloads,
            spill_bytes_before: spill.bytes_before,
            spill_bytes_after: spill.bytes_after,
            spill_stall_p99_us: spill.stall_p99_us,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::example_records;

    #[test]
    fn default_strategy_seeds_the_request_builder() {
        let svc = PlanService::new();
        assert_eq!(svc.default_strategy(), "greedy-size");
        assert_eq!(svc.request().strategy(), "greedy-size");
        let recs = example_records();
        let a = svc.plan(&recs, &svc.request()).unwrap();
        let b = svc
            .plan(&recs, &svc.request().with_strategy("greedy-size").unwrap())
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        let st = svc.stats();
        assert_eq!((st.cache_misses, st.cache_hits), (1, 1));
        assert!((st.cache_hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn cache_hit_rate_is_zero_before_any_lookup() {
        // The no-lookup hit rate is a defined 0.0, never NaN — rendered
        // stats must not poison dashboards on a fresh service.
        let svc = PlanService::new();
        let rate = svc.stats().cache_hit_rate();
        assert_eq!(rate, 0.0);
        assert!(!rate.is_nan());
        assert_eq!(PlanServiceStats::default().cache_hit_rate(), 0.0);
    }

    #[test]
    fn unknown_default_strategy_rejected() {
        assert!(PlanService::with_default_strategy("belady").is_err());
        let svc = PlanService::with_default_strategy("Greedy by Breadth").unwrap();
        assert_eq!(svc.request().strategy(), "greedy-breadth");
    }

    #[test]
    fn plan_graph_plans_the_extracted_records() {
        let svc = PlanService::new();
        let g = crate::models::example_net();
        let (records, plan, applied) = svc.plan_graph(&g, &svc.request()).unwrap();
        assert_eq!(plan.offsets.len(), records.len());
        assert_eq!(applied.breadth_delta(), 0);
        plan.validate(&records).unwrap();
    }

    #[test]
    fn plan_graph_dynamic_amortizes_decode_step_replans() {
        let svc = PlanService::new();
        let g = crate::models::blazeface();
        let decode_from = g.num_ops() / 2;
        let (dynamic, plan, applied) = svc
            .plan_graph_dynamic(&g, &svc.request(), decode_from)
            .unwrap();
        assert!(plan.is_complete());
        assert!(plan.passes >= 2, "a decode tail must produce multiple waves");
        assert_eq!(applied.breadth_delta(), 0);
        // The complete plan is feasible for the final sizes, and the peak
        // equals the monotone growth's high-water mark.
        plan.offset_plan().unwrap().validate(&dynamic.final_records()).unwrap();
        assert_eq!(plan.peak, *plan.growth.last().unwrap());
        // A decode loop over every op: the first sequence plans once per
        // distinct resolved prefix, the second plans nothing.
        for step in 0..dynamic.num_ops {
            let req = svc.request().with_dynamic(DynamicMode::Resolved(step));
            svc.plan_dynamic(&dynamic, &req).unwrap();
        }
        let misses = svc.stats().dynamic_misses;
        for step in 0..dynamic.num_ops {
            let req = svc.request().with_dynamic(DynamicMode::Resolved(step));
            svc.plan_dynamic(&dynamic, &req).unwrap();
        }
        assert_eq!(
            svc.stats().dynamic_misses,
            misses,
            "a repeated decode pass must perform zero planner invocations"
        );
    }

    #[test]
    fn plan_graph_applies_the_order_before_record_extraction() {
        let svc = PlanService::new();
        let g = crate::models::blazeface();
        let order = OrderStrategy::Annealed { seed: 3, budget: 20 };
        let req = svc.request().with_order(order);
        let (records, plan, applied) = svc.plan_graph(&g, &req).unwrap();
        // The plan is feasible for the *ordered* records, and the reported
        // breadth never regresses the natural order (annealing invariant).
        plan.validate(&records).unwrap();
        assert!(applied.order_breadth <= applied.natural_breadth);
        assert_eq!(applied.key(), order.key());
        // Re-planning the same configuration is an order-keyed cache hit.
        let _ = svc.plan_graph(&g, &req).unwrap();
        let st = svc.stats();
        assert_eq!((st.cache_misses, st.cache_hits), (1, 1));
        // Budget queries resolve under the same order: the cap's plan fits,
        // the next batch's does not.
        let budget = 2 * plan.total;
        let cap = svc.max_servable_batch(&records, &req, budget).unwrap();
        assert!(cap >= 1);
        let at_cap = svc.plan(&records, &req.with_batch(cap)).unwrap().total;
        let above = svc.plan(&records, &req.with_batch(cap + 1)).unwrap().total;
        assert!(at_cap <= budget && above > budget);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_shims_reach_the_same_cache_slots() {
        // The one-release compatibility promise: a positional-argument call
        // and its request-shaped replacement must share a slot.
        let svc = PlanService::new();
        let recs = example_records();
        let a = svc.plan_records(&recs, 2, None).unwrap();
        let b = svc.plan(&recs, &svc.request().with_batch(2)).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(svc.stats().cache_misses, 1);
        let order = OrderStrategy::MemoryAware;
        let c = svc.plan_records_ordered(&recs, 1, Some("greedy-size"), order).unwrap();
        let d = svc.plan(&recs, &svc.request().with_order(order)).unwrap();
        assert!(Arc::ptr_eq(&c, &d));
        assert_eq!(
            svc.max_servable_batch_ordered(&recs, 10 * a.total, None, order).unwrap(),
            svc.max_servable_batch(&recs, &svc.request().with_order(order), 10 * a.total)
                .unwrap()
        );
    }
}
