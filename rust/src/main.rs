//! `tensorarena` CLI — the leader entrypoint.
//!
//! Subcommands map one-to-one onto the paper's artifacts:
//!
//! ```text
//! tensorarena records  <model>                      # §3 usage records & profiles
//! tensorarena plan     <model> [shared|offset] [strategy] [--order O]
//!                      [--spill-dir DIR] [--batches 1,2,4]   # Figures 3–6 + plan spills
//! tensorarena table1                                # Table 1 (Shared Objects)
//! tensorarena table2 [--ratios]                     # Table 2 (Offset Calculation)
//! tensorarena cachesim <model> [kib]                # §1 locality claim
//! tensorarena serve [--model M] [--strategy S] [--order O] [--requests N]
//!                   [--max-batch B] [--wait-ms W] [--artifacts DIR]
//!                   [--mem-budget BYTES] [--plan-dir DIR] [--threads T]
//!                   [--dtype f32|f16|i8] [--dynamic [FRAC]] [--paged]
//!                   [--continuous] [--spill-policy refuse|spill]
//!                   [--spill-dir DIR] [--spill-watermark BYTES]
//!                   [--block-cap N]                 # E2E serving
//! tensorarena order-ablation [model] [--seed S] [--trials N] # §7.1 order table
//! tensorarena dynamic-ablation [model] [--frac F1,F2,...]    # §7 overhead table
//! tensorarena models                                # list zoo models
//! ```
//!
//! `--mem-budget` caps the planned arena: the server clamps batches to the
//! largest batch whose *planned* peak fits and refuses oversized bursts
//! with a typed error instead of OOMing (`BYTES` accepts `k`/`m`/`g`
//! suffixes). `--plan-dir` warm-starts the plan cache from a directory of
//! spilled plans at boot and persists it back at shutdown, so a restarted
//! server re-plans nothing it has already planned; `plan --spill-dir`
//! pre-populates such a directory offline.
//!
//! `--order` picks the execution-order strategy (`natural`, `memory-aware`,
//! `annealed`, or `annealed-s<seed>-t<trials>`): the graph is reordered
//! *before* record extraction, so plans, budget admission, and the plan
//! cache — including `--plan-dir` persistence, which keys files by the
//! order — all resolve under the served order. `order-ablation` prints the
//! §7.1 table (max breadth and arena per order) so you can pick an order
//! offline.
//!
//! `--dynamic [FRAC]` serves in the §7 wave-aware mode: the last `FRAC`
//! (default 0.5) of the graph's intermediate tensors resolve their sizes
//! just in time (one op before their producer), the arena is sized at the
//! worst-wave multi-pass peak, budget admission resolves under that peak,
//! and decode-step re-plans with an unchanged resolved-size prefix are
//! plan-cache hits with zero planner invocations. `dynamic-ablation`
//! prints the §7 overhead-vs-oracle table (multi-pass arena vs the
//! size-omniscient oracle) per model and dynamic fraction. Dynamic plans
//! are cached in memory only — `--plan-dir` persists static plans.
//!
//! `--paged` (implies `--dynamic` at its default fraction when not given)
//! serves the decode tail from the shared block pool instead of the
//! worst-wave preallocation: the resident arena holds only the static
//! prefix, tail tensors map into fixed-size blocks at the wave boundary
//! that materializes them and release the step they die, and budget
//! admission charges prefix peak + tail block demand. Outputs stay
//! bit-identical to the resident path.
//!
//! `--continuous` (implies `--paged`) replaces batch-and-drain with the
//! continuous-batching scheduler: up to `--max-batch` decode lanes run in
//! flight, finished lanes retire at §7 wave boundaries (their tail blocks
//! return to the shared pool) and queued requests are admitted into the
//! vacated slots immediately — no request waits for a batch to drain.
//! Budget admission charges the tail block demand *per live lane*, so the
//! resolved lane cap keeps every wave boundary under `--mem-budget`; the
//! bounded queue refuses overload with a typed `QueueFull`.
//!
//! `--spill-policy spill` turns the refusal boundary elastic (§tiered
//! memory): idle arena buffers past `--spill-watermark` (default 0 —
//! evict every idle buffer) are compressed into an in-memory spill tier,
//! and a request whose planned peak exceeds `--mem-budget` but fits
//! `budget + tier capacity` is admitted and served by demand-reloading —
//! bit-identically, at a reload-stall cost the stats line reports. The
//! default `refuse` keeps strict refusal byte-for-byte. `serve
//! --spill-dir` additionally mirrors evicted buffers to disk files
//! (atomic tmp+rename, adversarially validated at adoption) so a
//! restarted server re-adopts them; `--block-cap` tunes the shared block
//! pool's freelist cap (default 1024).
//!
//! `--dtype` picks the arena's element size class (`f32` default, `f16`,
//! `i8`): intermediate payloads are stored packed at the quantized element
//! size (per-record scale/zero-point chosen at each op's output), plans
//! and `--mem-budget` admission resolve under the shrunken footprint — i8
//! admits roughly 4× the f32 batch under the same budget — and served
//! outputs dequantize back to f32. Quantized serving is static-only:
//! `--dtype` refuses to combine with `--dynamic`, `--paged`, or
//! `--continuous`.
//!
//! Strategy names come from `planner::registry` — the single list the
//! tables, the plan cache, and this CLI all share.
//!
//! (Hand-rolled argument parsing: the offline registry has no clap.)

use tensorarena::coordinator::{self, ArenaStats, BatchPolicy, Router, SpillPolicy};
use tensorarena::exec::cachesim;
use tensorarena::models;
use tensorarena::planner::order::{
    anneal_order, apply_order, memory_aware_order, natural_order, order_max_breadth,
    reorder_graph,
};
use tensorarena::planner::{
    offset, registry, Dtype, DynamicMode, DynamicRecords, OffsetPlanner, OrderStrategy,
    PlanCache, PlanRequest, PlanService, SharedObjectPlanner,
};
use tensorarena::records::UsageRecords;
use tensorarena::report::{self, MIB};
use tensorarena::rng::SplitMix64;
use std::path::Path;
use std::sync::Arc;

/// Parse a byte count with an optional `k`/`m`/`g` (KiB/MiB/GiB) suffix.
fn parse_bytes(s: &str) -> Option<usize> {
    let t = s.trim();
    let (digits, mult): (&str, usize) = match t.chars().last()? {
        'k' | 'K' => (&t[..t.len() - 1], 1 << 10),
        'm' | 'M' => (&t[..t.len() - 1], 1 << 20),
        'g' | 'G' => (&t[..t.len() - 1], 1 << 30),
        _ => (t, 1),
    };
    digits.parse::<usize>().ok()?.checked_mul(mult)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(String::as_str) {
        Some("records") => cmd_records(&args[1..]),
        Some("plan") => cmd_plan(&args[1..]),
        Some("table1") => {
            print!("{}", report::table1().render());
            0
        }
        Some("table2") => cmd_table2(&args[1..]),
        Some("cachesim") => cmd_cachesim(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("order-ablation") => cmd_order_ablation(&args[1..]),
        Some("dynamic-ablation") => cmd_dynamic_ablation(&args[1..]),
        Some("models") => {
            for m in models::ZOO {
                println!("{m}");
            }
            println!("example");
            println!("l2_cnn");
            0
        }
        _ => {
            eprintln!(
                "usage: tensorarena <records|plan|table1|table2|cachesim|serve|order-ablation|dynamic-ablation|models> ...\n\
                 see README.md for details"
            );
            2
        }
    };
    std::process::exit(code);
}

fn load_model(name: &str) -> Option<tensorarena::graph::Graph> {
    let g = models::by_name(name);
    if g.is_none() {
        eprintln!("unknown model '{name}'; try `tensorarena models`");
    }
    g
}

fn cmd_records(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("usage: tensorarena records <model>");
        return 2;
    };
    let Some(g) = load_model(name) else { return 2 };
    let recs = UsageRecords::from_graph(&g);
    let p = recs.profiles();
    println!(
        "{name}: {} ops, {} intermediate tensors, naive {:.3} MiB, weights {:.3} MiB",
        g.num_ops(),
        recs.len(),
        recs.naive_total() as f64 / MIB,
        g.weight_bytes() as f64 / MIB,
    );
    println!(
        "lower bounds: shared-objects {:.3} MiB (sum of {} positional maxima), offsets {:.3} MiB (max breadth)",
        p.shared_objects_lower_bound() as f64 / MIB,
        p.positional_maximums().len(),
        p.offset_lower_bound() as f64 / MIB,
    );
    println!("\n id first last      bytes  tensor");
    for r in &recs.records {
        let tname = r
            .tensor
            .map(|t| g.tensor(t).name.clone())
            .unwrap_or_default();
        println!(
            "{:>3} {:>5} {:>4} {:>10}  {tname}",
            r.id, r.first_op, r.last_op, r.size
        );
    }
    0
}

fn cmd_plan(args: &[String]) -> i32 {
    // Split flags (--spill-dir DIR, --batches CSV, --order O) from
    // positionals.
    let mut spill_dir: Option<String> = None;
    let mut batches: Vec<usize> = vec![1];
    let mut order = OrderStrategy::Natural;
    let mut pos: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--order" => {
                let Some(o) = args.get(i + 1).and_then(|v| registry::order_strategy(v)) else {
                    eprintln!(
                        "--order wants one of: {} (annealed also accepts \
                         annealed-s<seed>-t<trials>)",
                        registry::ORDER_KEYS.join(", ")
                    );
                    return 2;
                };
                order = o;
                i += 2;
            }
            "--spill-dir" => {
                let Some(d) = args.get(i + 1) else {
                    eprintln!("--spill-dir wants a directory");
                    return 2;
                };
                spill_dir = Some(d.clone());
                i += 2;
            }
            "--batches" => {
                let parsed: Option<Vec<usize>> = args.get(i + 1).and_then(|v| {
                    v.split(',')
                        .map(|b| b.trim().parse::<usize>().ok().filter(|&b| b > 0))
                        .collect::<Option<Vec<usize>>>()
                });
                let Some(list) = parsed.filter(|l| !l.is_empty()) else {
                    eprintln!("--batches wants a comma-separated list of positive batch sizes");
                    return 2;
                };
                batches = list;
                i += 2;
            }
            other => {
                pos.push(other);
                i += 1;
            }
        }
    }
    if batches != [1] && spill_dir.is_none() {
        eprintln!("--batches only applies together with --spill-dir; ignoring");
    }
    let Some(&name) = pos.first() else {
        eprintln!(
            "usage: tensorarena plan <model> [shared|offset] [strategy] [--order O] [--spill-dir DIR] [--batches 1,2,4]"
        );
        return 2;
    };
    let approach = pos.get(1).copied().unwrap_or("offset");
    let strategy = pos.get(2).copied().unwrap_or("greedy-size");
    let Some(g) = load_model(name) else { return 2 };
    // Reorder *before* record extraction: every number below — and every
    // spilled plan file — is for the ordered graph.
    let (g, applied) = apply_order(&g, order);
    if !order.is_natural() {
        println!(
            "order {}: max breadth {:.3} MiB vs natural {:.3} MiB",
            applied.key(),
            applied.order_breadth as f64 / MIB,
            applied.natural_breadth as f64 / MIB,
        );
    }
    let recs = UsageRecords::from_graph(&g);
    let p = recs.profiles();
    match approach {
        "shared" => {
            if spill_dir.is_some() {
                eprintln!("--spill-dir only applies to offset plans (the arena format); ignoring");
            }
            let Some(planner) = registry::shared_strategy(strategy) else {
                eprintln!(
                    "unknown shared strategy '{strategy}' (known: {})",
                    registry::SHARED_KEYS.join(", ")
                );
                return 2;
            };
            let plan = planner.plan(&recs);
            if let Err(e) = plan.validate(&recs) {
                eprintln!("INFEASIBLE PLAN: {e}");
                return 1;
            }
            println!(
                "{} on {name}: {} objects, total {:.3} MiB (lower bound {:.3} MiB, naive {:.3} MiB)",
                planner.name(),
                plan.num_objects(),
                plan.total_size() as f64 / MIB,
                p.shared_objects_lower_bound() as f64 / MIB,
                recs.naive_total() as f64 / MIB,
            );
            for (i, &sz) in plan.object_sizes.iter().enumerate() {
                let members: Vec<String> = recs
                    .records
                    .iter()
                    .filter(|r| plan.assignment[r.id] == i)
                    .map(|r| format!("t{}({},{})", r.id, r.first_op, r.last_op))
                    .collect();
                println!("  object {i:>3} {sz:>10} B: {}", members.join(" "));
            }
        }
        "offset" => {
            let Some(planner) = registry::offset_strategy(strategy) else {
                eprintln!(
                    "unknown offset strategy '{strategy}' (known: {})",
                    registry::OFFSET_KEYS.join(", ")
                );
                return 2;
            };
            let plan = planner.plan(&recs);
            if let Err(e) = plan.validate(&recs) {
                eprintln!("INFEASIBLE PLAN: {e}");
                return 1;
            }
            println!(
                "{} on {name}: arena {:.3} MiB (lower bound {:.3} MiB, naive {:.3} MiB)",
                planner.name(),
                plan.total_size() as f64 / MIB,
                p.offset_lower_bound() as f64 / MIB,
                recs.naive_total() as f64 / MIB,
            );
            let mut ids: Vec<usize> = (0..recs.len()).collect();
            ids.sort_by_key(|&i| plan.offsets[i]);
            for i in ids.iter().take(40) {
                let r = &recs.records[*i];
                println!(
                    "  t{:<3} offset {:>10} size {:>10} live [{}, {}]",
                    r.id, plan.offsets[r.id], r.size, r.first_op, r.last_op
                );
            }
            if recs.len() > 40 {
                println!("  ... ({} more)", recs.len() - 40);
            }
            if recs.num_ops <= 120 {
                println!("\n{}", report::render_offset_timeline(&recs, &plan, 16));
            }
            if let Some(dir) = &spill_dir {
                // Populate a plan directory `serve --plan-dir` can
                // warm-start from: one file per requested batch.
                let base = match PlanRequest::new().with_strategy(strategy) {
                    Ok(req) => req.with_order(order),
                    Err(e) => {
                        eprintln!("building spill request: {e}");
                        return 1;
                    }
                };
                let cache = PlanCache::new();
                for &b in &batches {
                    if let Err(e) = cache.get_or_plan(&recs, &base.with_batch(b)) {
                        eprintln!("planning batch {b} for spill: {e}");
                        return 1;
                    }
                }
                match cache.persist_dir(Path::new(dir)) {
                    Ok(report) => println!(
                        "spilled {} plan(s) (batches {:?}) to {dir}",
                        report.written, batches
                    ),
                    Err(e) => {
                        eprintln!("spilling to {dir}: {e}");
                        return 1;
                    }
                }
            }
        }
        _ => {
            eprintln!("approach must be 'shared' or 'offset'");
            return 2;
        }
    }
    0
}

fn cmd_table2(args: &[String]) -> i32 {
    let t = report::table2();
    print!("{}", t.render());
    if args.iter().any(|a| a == "--ratios") {
        // §1: "up to 10.5× smaller memory footprint than ... without one"
        println!("\nNaive / best-strategy ratio per network:");
        let naive = &t.rows.last().unwrap().1;
        for (i, col) in t.columns.iter().enumerate() {
            let best = t
                .rows
                .iter()
                .filter(|(n, _)| n != "Naive" && n != "Lower Bound")
                .map(|(_, v)| v[i])
                .fold(f64::INFINITY, f64::min);
            println!("  {col:>14}: {:.1}x", naive[i] / best);
        }
    }
    0
}

fn cmd_cachesim(args: &[String]) -> i32 {
    let Some(name) = args.first() else {
        eprintln!("usage: tensorarena cachesim <model> [cache-KiB ...]");
        return 2;
    };
    let Some(g) = load_model(name) else { return 2 };
    let recs = UsageRecords::from_graph(&g);
    let planned = cachesim::simulate(&g, &recs, &offset::GreedyBySize.plan(&recs));
    let naive = cachesim::simulate(&g, &recs, &offset::NaiveOffset.plan(&recs));
    let sizes: Vec<usize> = if args.len() > 1 {
        args[1..].iter().filter_map(|a| a.parse().ok()).collect()
    } else {
        vec![32, 128, 256, 512, 1024, 2048, 4096]
    };
    println!(
        "{name}: LRU hit rate, Greedy-by-Size arena vs Naive (cold misses {} vs {})",
        planned.cold_misses(),
        naive.cold_misses()
    );
    println!("{:>10} {:>10} {:>10} {:>8}", "cache KiB", "planned", "naive", "delta");
    for kib in sizes {
        let hp = planned.hit_rate(kib * 1024);
        let hn = naive.hit_rate(kib * 1024);
        println!("{kib:>10} {hp:>10.4} {hn:>10.4} {:>+8.4}", hp - hn);
    }
    0
}

/// The §7.1 order-ablation table: for each model, the max operator breadth
/// (the §5.1 lower bound) under the natural / memory-aware / annealed
/// orders, plus the Greedy-by-Size arena under the natural and annealed
/// orders — everything needed to decide whether `serve --order annealed`
/// is worth it for a model, offline.
fn cmd_order_ablation(args: &[String]) -> i32 {
    let mut seed = OrderStrategy::DEFAULT_ANNEAL_SEED;
    let mut trials = OrderStrategy::DEFAULT_ANNEAL_BUDGET;
    let mut pos: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--seed" => {
                let Some(s) = args.get(i + 1).and_then(|v| v.parse().ok()) else {
                    eprintln!("--seed wants a number");
                    return 2;
                };
                seed = s;
                i += 2;
            }
            "--trials" => {
                let Some(t) = args.get(i + 1).and_then(|v| v.parse().ok()) else {
                    eprintln!("--trials wants a number");
                    return 2;
                };
                trials = t;
                i += 2;
            }
            other => {
                pos.push(other);
                i += 1;
            }
        }
    }
    let graphs = match pos.first() {
        Some(&name) => match load_model(name) {
            Some(g) => vec![g],
            None => return 2,
        },
        None => models::all_zoo(),
    };
    println!(
        "order ablation (annealed-s{seed}-t{trials}); breadth = §5.1 lower bound, arena = Greedy by Size:"
    );
    println!(
        "{:<14} {:>13} {:>13} {:>13} {:>13} {:>13} {:>8}",
        "network",
        "natural br",
        "mem-aware br",
        "annealed br",
        "natural arena",
        "annealed",
        "delta"
    );
    for g in graphs {
        let natural_br = order_max_breadth(&g, &natural_order(&g));
        let greedy_br = order_max_breadth(&g, &memory_aware_order(&g));
        // Anneal once; breadth and arena columns come from the same order.
        let annealed = anneal_order(&g, seed, trials);
        let annealed_br = order_max_breadth(&g, &annealed);
        let base = offset::GreedyBySize
            .plan(&UsageRecords::from_graph(&g))
            .total_size();
        let annealed_arena = offset::GreedyBySize
            .plan(&UsageRecords::from_graph(&reorder_graph(&g, &annealed)))
            .total_size();
        println!(
            "{:<14} {:>9.3} MiB {:>9.3} MiB {:>9.3} MiB {:>9.3} MiB {:>9.3} MiB {:>+7.2}%",
            g.name,
            natural_br as f64 / MIB,
            greedy_br as f64 / MIB,
            annealed_br as f64 / MIB,
            base as f64 / MIB,
            annealed_arena as f64 / MIB,
            (annealed_arena as f64 / base as f64 - 1.0) * 100.0,
        );
    }
    0
}

/// The §7 overhead-vs-oracle table: for each model and decode-tail
/// fraction, the number of tensors resolving late, the planner waves, the
/// multi-pass (worst-wave) arena, the size-omniscient oracle arena, and
/// the overhead ratio — everything needed to decide what dynamic shapes
/// cost a model before turning on `serve --dynamic`.
fn cmd_dynamic_ablation(args: &[String]) -> i32 {
    let mut fracs: Vec<f64> = vec![0.1, 0.25, 0.5, 0.9];
    let mut pos: Vec<&str> = Vec::new();
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--frac" => {
                let parsed: Option<Vec<f64>> = args.get(i + 1).and_then(|v| {
                    v.split(',')
                        .map(|f| f.trim().parse::<f64>().ok().filter(|&f| f > 0.0 && f <= 1.0))
                        .collect::<Option<Vec<f64>>>()
                });
                let Some(list) = parsed.filter(|l| !l.is_empty()) else {
                    eprintln!("--frac wants a comma-separated list of fractions in (0, 1]");
                    return 2;
                };
                fracs = list;
                i += 2;
            }
            other => {
                pos.push(other);
                i += 1;
            }
        }
    }
    let graphs = match pos.first() {
        Some(&name) => match load_model(name) {
            Some(g) => vec![g],
            None => return 2,
        },
        None => models::all_zoo(),
    };
    println!(
        "dynamic-shape ablation (§7): decode-tail profile, multi-pass arena vs size-omniscient oracle:"
    );
    println!(
        "{:<14} {:>8} {:>8} {:>6} {:>13} {:>13} {:>9}",
        "network", "dyn frac", "dyn recs", "waves", "multi-pass", "oracle", "overhead"
    );
    for g in graphs {
        let recs = UsageRecords::from_graph(&g);
        let oracle = offset::GreedyBySize.plan(&recs).total_size();
        for &frac in &fracs {
            let ops = g.num_ops();
            let decode_from = ops.saturating_sub((ops as f64 * frac).ceil() as usize);
            let dynamic = DynamicRecords::decode_tail(&recs, decode_from);
            let mp = registry::dynamic_planner().plan(&dynamic);
            // The oracle is fraction-independent and already planned above;
            // dividing here avoids re-planning both sides per row.
            let overhead = if oracle == 0 { 1.0 } else { mp.peak as f64 / oracle as f64 };
            println!(
                "{:<14} {:>8.2} {:>8} {:>6} {:>9.3} MiB {:>9.3} MiB {:>8.3}x",
                g.name,
                frac,
                dynamic.num_dynamic(),
                mp.passes,
                mp.peak as f64 / MIB,
                oracle as f64 / MIB,
                overhead,
            );
        }
    }
    0
}

fn cmd_serve(args: &[String]) -> i32 {
    // Parse --artifacts DIR --requests N --max-batch B --wait-ms W
    // --model M --strategy S --mem-budget BYTES --plan-dir DIR. With PJRT
    // artifacts (and the `pjrt` feature) the AOT path runs; otherwise the
    // pure-Rust ExecutorEngine path serves `--model` through a shared
    // PlanService.
    let mut dir = "artifacts".to_string();
    let mut dir_given = false;
    let mut requests = 256usize;
    let mut max_batch = 8usize;
    let mut wait_ms = 2u64;
    let mut model = "blazeface".to_string();
    let mut strategy = PlanService::DEFAULT_STRATEGY.to_string();
    let mut order = OrderStrategy::Natural;
    let mut mem_budget: Option<usize> = None;
    let mut plan_dir: Option<String> = None;
    let mut dynamic: Option<f64> = None;
    let mut paged = false;
    let mut continuous = false;
    let mut threads = 1usize;
    let mut dtype = Dtype::F32;
    let mut spill_policy = SpillPolicy::Refuse;
    let mut spill_dir: Option<String> = None;
    let mut spill_watermark = 0usize;
    let mut block_cap = tensorarena::arena::paged::DEFAULT_BLOCK_SHELF_CAP;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--paged" => {
                paged = true;
                i += 1;
            }
            "--continuous" => {
                // Continuous batching is lane-granular paged serving,
                // which in turn is a mode of wave-aware serving.
                continuous = true;
                paged = true;
                i += 1;
            }
            "--dynamic" => {
                // Optional fraction operand: `--dynamic 0.25`. A following
                // flag (or nothing) means the default tail fraction.
                match args.get(i + 1).and_then(|v| v.parse::<f64>().ok()) {
                    Some(f) if f > 0.0 && f <= 1.0 => {
                        dynamic = Some(f);
                        i += 2;
                    }
                    Some(_) => {
                        eprintln!("--dynamic wants a fraction in (0, 1]");
                        return 2;
                    }
                    None => {
                        dynamic = Some(0.5);
                        i += 1;
                    }
                }
            }
            "--order" => {
                let Some(o) = args.get(i + 1).and_then(|v| registry::order_strategy(v)) else {
                    eprintln!(
                        "--order wants one of: {} (annealed also accepts \
                         annealed-s<seed>-t<trials>)",
                        registry::ORDER_KEYS.join(", ")
                    );
                    return 2;
                };
                order = o;
                i += 2;
            }
            "--artifacts" => {
                dir = args.get(i + 1).cloned().unwrap_or(dir);
                dir_given = true;
                i += 2;
            }
            "--requests" => {
                requests = args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or(requests);
                i += 2;
            }
            "--batch" | "--max-batch" => {
                max_batch = args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or(max_batch);
                i += 2;
            }
            "--wait-ms" => {
                wait_ms = args.get(i + 1).and_then(|v| v.parse().ok()).unwrap_or(wait_ms);
                i += 2;
            }
            "--model" => {
                model = args.get(i + 1).cloned().unwrap_or(model);
                i += 2;
            }
            "--strategy" => {
                strategy = args.get(i + 1).cloned().unwrap_or(strategy);
                i += 2;
            }
            "--mem-budget" => {
                let Some(b) = args.get(i + 1).and_then(|v| parse_bytes(v)) else {
                    eprintln!("--mem-budget wants a byte count (suffixes k/m/g allowed)");
                    return 2;
                };
                mem_budget = Some(b);
                i += 2;
            }
            "--plan-dir" => {
                let Some(d) = args.get(i + 1) else {
                    eprintln!("--plan-dir wants a directory");
                    return 2;
                };
                plan_dir = Some(d.clone());
                i += 2;
            }
            "--threads" => {
                let Some(t) = args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) else {
                    eprintln!("--threads wants a positive worker count");
                    return 2;
                };
                threads = t.max(1);
                i += 2;
            }
            "--dtype" => {
                let Some(d) = args.get(i + 1).and_then(|v| v.parse::<Dtype>().ok()) else {
                    eprintln!("--dtype wants one of: f32, f16, i8");
                    return 2;
                };
                dtype = d;
                i += 2;
            }
            "--spill-policy" => {
                let Some(p) = args.get(i + 1).and_then(|v| SpillPolicy::parse(v)) else {
                    eprintln!("--spill-policy wants 'refuse' or 'spill'");
                    return 2;
                };
                spill_policy = p;
                i += 2;
            }
            "--spill-dir" => {
                let Some(d) = args.get(i + 1) else {
                    eprintln!("--spill-dir wants a directory");
                    return 2;
                };
                spill_dir = Some(d.clone());
                i += 2;
            }
            "--spill-watermark" => {
                let Some(w) = args.get(i + 1).and_then(|v| parse_bytes(v)) else {
                    eprintln!("--spill-watermark wants a byte count (suffixes k/m/g allowed)");
                    return 2;
                };
                spill_watermark = w;
                i += 2;
            }
            "--block-cap" => {
                let Some(c) = args.get(i + 1).and_then(|v| v.parse::<usize>().ok()) else {
                    eprintln!("--block-cap wants a shelf capacity (block count)");
                    return 2;
                };
                block_cap = c;
                i += 2;
            }
            other => {
                eprintln!("unknown flag {other}");
                return 2;
            }
        }
    }
    if dtype != Dtype::F32 && (dynamic.is_some() || paged || continuous) {
        eprintln!(
            "--dtype {dtype} cannot combine with --dynamic/--paged/--continuous: i8/f16 size \
             classes are static-mode only"
        );
        return 2;
    }
    #[cfg(feature = "pjrt")]
    {
        if tensorarena::runtime::Runtime::discover_variants(std::path::Path::new(&dir), "model")
            .is_ok()
        {
            if !order.is_natural() {
                eprintln!(
                    "--order {} ignored: the PJRT AOT path executes the compiled order; \
                     ordering applies to the pure-Rust executor path only",
                    order.key()
                );
            }
            if dynamic.is_some() {
                eprintln!(
                    "--dynamic ignored: the PJRT AOT path compiles static shapes; \
                     wave-aware serving applies to the pure-Rust executor path only"
                );
            }
            if paged {
                eprintln!(
                    "--paged ignored: the PJRT AOT path compiles static shapes; \
                     paged decode tails apply to the pure-Rust executor path only"
                );
            }
            if continuous {
                eprintln!(
                    "--continuous ignored: the PJRT AOT path executes whole compiled \
                     batches; lane-granular serving applies to the pure-Rust executor \
                     path only"
                );
            }
            if threads > 1 {
                eprintln!(
                    "--threads ignored: the PJRT AOT path runs the compiled executable; \
                     multicore execution applies to the pure-Rust executor path only"
                );
            }
            if dtype != Dtype::F32 {
                eprintln!(
                    "--dtype {dtype} ignored: the PJRT AOT path executes compiled f32 \
                     kernels; quantized size classes apply to the pure-Rust executor path only"
                );
            }
            if spill_policy != SpillPolicy::Refuse || spill_dir.is_some() {
                eprintln!(
                    "--spill-policy/--spill-dir ignored: the PJRT AOT path has no arena \
                     pool to evict from; the spill tier applies to the pure-Rust executor \
                     path only"
                );
            }
            return match serve_bench(&dir, &strategy, requests, max_batch, wait_ms, mem_budget) {
                Ok(()) => 0,
                Err(e) => {
                    eprintln!("serve failed: {e:#}");
                    1
                }
            };
        }
        eprintln!("no artifacts in {dir}; serving the pure-Rust executor path");
    }
    if dir_given && !cfg!(feature = "pjrt") {
        eprintln!(
            "--artifacts {dir} ignored: this build has no PJRT runtime (enable the `pjrt` \
             feature); serving the pure-Rust executor path"
        );
    }
    match serve_pure(
        &model,
        &strategy,
        order,
        dtype,
        requests,
        max_batch,
        wait_ms,
        mem_budget,
        plan_dir.as_deref(),
        dynamic,
        paged,
        continuous,
        threads,
        spill_policy,
        spill_dir.as_deref(),
        spill_watermark,
        block_cap,
    ) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

/// Artifact-free serving: the arena [`tensorarena::exec::Executor`] behind
/// the coordinator, planned through one shared [`PlanService`] whose
/// cache-hit and pool-reuse counters are reported next to the latency
/// numbers. With `mem_budget`, the server clamps batches to the planned
/// envelope and refuses what cannot fit; with `plan_dir`, the plan cache
/// is warm-started at boot and persisted back at shutdown. With a
/// non-natural `order`, the graph is reordered before record extraction,
/// so the arena, the admission envelope, and every plan-dir file are for
/// the served order. With `dynamic`, the last `frac` of the tensors
/// resolve late (§7): the engine serves wave-aware, the arena and budget
/// resolve under the worst-wave multi-pass peak, and decode-step re-plans
/// are amortized through the resolved-prefix plan cache. With `threads > 1`
/// the engine's executor runs batch lanes and independent ops on a worker
/// pool (bit-identical outputs — see `docs/ARCHITECTURE.md`). With `paged`
/// (which implies `dynamic` at its default fraction), the decode tail is
/// served from the shared block pool: only the static prefix stays
/// resident, and admission charges prefix peak + tail block demand. With
/// `continuous` (which implies `paged`), the worker runs the
/// continuous-batching scheduler — up to the cap decode lanes in flight,
/// wave-boundary admission, bounded-queue backpressure — and admission
/// charges the tail demand per live lane; the storm below then keeps a
/// sliding window of outstanding requests so admissions actually overlap
/// in-flight decode loops instead of flooding the bounded queue. With a
/// non-f32 `dtype`, arena payloads are stored packed at the i8/f16 size
/// class (per-record scale/zero-point, outputs dequantized back to f32)
/// and the plans plus the admission envelope resolve under the shrunken
/// footprint; quantized serving is static-only, so the caller has already
/// refused the dynamic/paged/continuous combinations. With
/// `spill_policy == Spill` (or a `spill_dir`), the pool evicts idle
/// buffers past `spill_watermark` into the compressed spill tier — disk-
/// mirrored when a directory is given, re-adopted at boot — and admission
/// turns elastic: over-budget requests that fit `budget + tier capacity`
/// serve by demand-reloading, bit-identically, with the eviction/reload
/// counters reported next to the latency numbers.
#[allow(clippy::too_many_arguments)]
fn serve_pure(
    model: &str,
    strategy: &str,
    order: OrderStrategy,
    dtype: Dtype,
    requests: usize,
    max_batch: usize,
    wait_ms: u64,
    mem_budget: Option<usize>,
    plan_dir: Option<&str>,
    dynamic: Option<f64>,
    paged: bool,
    continuous: bool,
    threads: usize,
    spill_policy: SpillPolicy,
    spill_dir: Option<&str>,
    spill_watermark: usize,
    block_cap: usize,
) -> Result<(), String> {
    use tensorarena::arena::paged::BLOCK_WORDS;
    use tensorarena::arena::spill::SpillTier;
    use tensorarena::coordinator::engine::ExecutorEngine;

    // Paged serving is a mode of wave-aware serving: without an explicit
    // fraction, the default decode tail pages.
    let dynamic = if paged { dynamic.or(Some(0.5)) } else { dynamic };

    let Some(g) = load_model(model) else {
        return Err(format!("unknown model '{model}'"));
    };
    let service = PlanService::shared();
    // One typed identity for the whole serving configuration: every warm
    // start, budget query, engine construction, and stats line below keys
    // off (re-batched / re-resolved copies of) this request.
    let req = PlanRequest::new()
        .with_strategy(strategy)
        .map_err(|e| e.to_string())?
        .with_order(order)
        .with_dtype(dtype);
    if dtype != Dtype::F32 {
        println!(
            "quantized serving: {dtype} size class ({} B/elem vs 4 B f32)",
            dtype.element_bytes(),
        );
    }
    // Apply the order up front: `recs` below are the *served* records, so
    // warm starts, budget resolution, and the final stats all agree with
    // what the engine (which re-derives the same deterministic order)
    // plans.
    let (g, applied) = apply_order(&g, order);
    if !order.is_natural() {
        println!(
            "order {}: max breadth {:.1} KiB vs natural {:.1} KiB",
            applied.key(),
            applied.order_breadth as f64 / 1024.0,
            applied.natural_breadth as f64 / 1024.0,
        );
    }
    let recs = UsageRecords::from_graph(&g);
    // The spill tier exists when the policy (or a directory) asks for it;
    // under the default refuse policy with no directory, nothing below
    // changes — the pool has no tier and every line prints as before.
    let spilling = spill_policy == SpillPolicy::Spill || spill_dir.is_some();
    if spilling {
        let tier = match spill_dir {
            Some(d) => {
                let tier = SpillTier::with_dir(Path::new(d))
                    .map_err(|e| format!("opening spill dir {d}: {e}"))?;
                let report =
                    tier.load_dir().map_err(|e| format!("adopting spill dir {d}: {e}"))?;
                println!(
                    "spill dir {d}: adopted {} buffer(s), {} suspect skip(s)",
                    report.loaded,
                    report.skipped(),
                );
                tier
            }
            None => SpillTier::new(),
        };
        service.pool().configure_spill(Arc::new(tier), spill_watermark);
        println!(
            "spill tier: policy {}, watermark {:.1} KiB{}",
            if spill_policy == SpillPolicy::Spill { "spill" } else { "refuse" },
            spill_watermark as f64 / 1024.0,
            spill_dir.map(|d| format!(", mirrored to {d}")).unwrap_or_default(),
        );
    }
    if let Some(dir) = plan_dir {
        let report = service
            .warm_start(Path::new(dir), &recs, &req)
            .map_err(|e| format!("warm-starting from {dir}: {e}"))?;
        println!(
            "plan dir {dir}: warm-started {} plan(s), {} suspect skip(s), {} foreign, {} stale-order",
            report.loaded,
            report.skipped(),
            report.skipped_foreign,
            report.skipped_stale_order,
        );
    }
    // The decode-tail profile, when serving dynamic shapes: the last
    // `frac` of the ops' outputs resolve one op before their producer.
    let decode = dynamic.map(|frac| {
        let ops = g.num_ops();
        let decode_from = ops.saturating_sub((ops as f64 * frac).ceil() as usize);
        (decode_from, DynamicRecords::decode_tail(&recs, decode_from))
    });
    if let Some((decode_from, dyn_recs)) = &decode {
        let mp = service
            .plan_dynamic(dyn_recs, &req.with_dynamic(DynamicMode::FullyResolved))
            .map_err(|e| e.to_string())?;
        let oracle = offset::GreedyBySize.plan(&recs).total_size();
        let overhead = if oracle == 0 { 1.0 } else { mp.peak as f64 / oracle as f64 };
        println!(
            "{model} dynamic (§7): {} of {} tensors resolve late (from op {decode_from}), \
             {} planner waves; worst-wave peak {:.1} KiB, overhead vs oracle {:.3}x",
            dyn_recs.num_dynamic(),
            dyn_recs.len(),
            mp.passes,
            mp.peak as f64 / 1024.0,
            overhead,
        );
        if paged {
            let prefix = service
                .plan_dynamic(dyn_recs, &req.with_dynamic(DynamicMode::Resolved(0)))
                .map_err(|e| e.to_string())?;
            let demand = dyn_recs.tail_block_demand(BLOCK_WORDS);
            println!(
                "paged: resident prefix {:.1} KiB + {demand} tail block(s) of {} B from the \
                 shared pool (vs {:.1} KiB worst-wave preallocation)",
                prefix.peak as f64 / 1024.0,
                BLOCK_WORDS * 4,
                mp.peak as f64 / 1024.0,
            );
        }
        if plan_dir.is_some() {
            println!(
                "note: dynamic plans are cached in memory only; --plan-dir persists static plans"
            );
        }
    } else {
        let plan = service.plan(&recs, &req).map_err(|e| e.to_string())?;
        println!(
            "{model} arena: {:.1} KiB planned vs {:.1} KiB naive ({:.1}x)",
            plan.total_size() as f64 / 1024.0,
            recs.naive_total() as f64 / 1024.0,
            recs.naive_total() as f64 / plan.total_size().max(1) as f64,
        );
    }
    if let Some(budget) = mem_budget {
        let cap = match &decode {
            // Paged admission mirrors the engine's walk: the footprint is
            // prefix peak (scales with batch) plus the tail block term —
            // flat for drain serving (one lane's stripes map at a time),
            // per live lane for continuous serving (every lane keeps its
            // own tail mapped across wave boundaries).
            Some((_, dyn_recs)) if paged => {
                let mut best = 0;
                for b in 1..=max_batch.max(1) {
                    let lanes = if continuous { b } else { 1 };
                    let tail =
                        dyn_recs.tail_block_demand_lanes(BLOCK_WORDS, lanes) * BLOCK_WORDS * 4;
                    let p = service
                        .plan_dynamic(
                            dyn_recs,
                            &req.with_batch(b).with_dynamic(DynamicMode::Resolved(0)),
                        )
                        .map_err(|e| e.to_string())?
                        .peak;
                    if p + tail <= budget {
                        best = b;
                    } else {
                        break;
                    }
                }
                best
            }
            Some((_, dyn_recs)) => service
                .max_servable_batch_dynamic(dyn_recs, &req, budget)
                .map_err(|e| e.to_string())?,
            None => service
                .max_servable_batch(&recs, &req, budget)
                .map_err(|e| e.to_string())?,
        };
        println!(
            "mem budget {:.1} KiB: max servable batch {cap}{}",
            budget as f64 / 1024.0,
            if cap < max_batch { " (clamping the batcher)" } else { "" },
        );
    }
    let in_elems = g.tensor(g.inputs[0]).num_elements();

    let mut router = Router::new();
    {
        let service = Arc::clone(&service);
        let model_name = model.to_string();
        let decode_from = decode.as_ref().map(|(from, _)| *from);
        router.register(
            model,
            move || {
                let g = models::by_name(&model_name).expect("model exists");
                let engine = match decode_from {
                    Some(from) if paged => {
                        ExecutorEngine::for_request_paged(&g, service, &req, from, 42)
                    }
                    Some(from) => {
                        ExecutorEngine::for_request_dynamic(&g, service, &req, from, 42)
                    }
                    None => ExecutorEngine::for_request(&g, service, &req, 42),
                };
                let engine =
                    engine.expect("engine").with_max_batch(max_batch).with_threads(threads);
                let engine = if continuous { engine.with_continuous() } else { engine };
                Box::new(engine)
            },
            BatchPolicy {
                max_batch,
                max_wait: std::time::Duration::from_millis(wait_ms),
                mem_budget,
                continuous,
                spill: spill_policy,
                block_shelf_cap: block_cap,
                ..BatchPolicy::default()
            },
        )
        .map_err(|e| e.to_string())?;
    }

    let mut rng = SplitMix64::new(42);
    let mut input = vec![0f32; in_elems];
    let t0 = std::time::Instant::now();
    // The continuous storm keeps a bounded window of outstanding requests:
    // enough to keep every lane busy and new admissions overlapping
    // in-flight decode loops, but below the server's queue depth so the
    // closed-loop driver never trips its own backpressure. The drain storm
    // submits everything up front, as before.
    let window = if continuous {
        (max_batch.max(1) + BatchPolicy::default().queue_depth / 2).min(requests.max(1))
    } else {
        requests.max(1)
    };
    let mut recv_one = |rx: std::sync::mpsc::Receiver<tensorarena::coordinator::Response>| {
        match rx.recv() {
            Ok(Ok(_)) => true,
            Ok(Err(e)) => {
                eprintln!("request error: {e}");
                false
            }
            Err(_) => {
                eprintln!("worker died");
                false
            }
        }
    };
    let mut pending = std::collections::VecDeque::with_capacity(window);
    let mut ok = 0usize;
    for _ in 0..requests {
        if pending.len() >= window && recv_one(pending.pop_front().expect("window is non-empty")) {
            ok += 1;
        }
        rng.fill_f32(&mut input, 1.0);
        pending.push_back(router.submit(model, input.clone()));
    }
    for rx in pending {
        if recv_one(rx) {
            ok += 1;
        }
    }
    let wall = t0.elapsed();
    // Snapshot the burst before probing, so the reported latency/batch
    // numbers describe the measured workload, not the probe.
    let snap = router.server(model).unwrap().metrics().snapshot();
    // Under a budget, probe the envelope: one pre-batched burst at the
    // nominal max batch. If the budget clamped the server below it, the
    // burst is refused with the typed admission error (and counted) —
    // the MAFAT-style behaviour an OOMing server cannot offer.
    if mem_budget.is_some() {
        let probe = vec![0f32; in_elems * max_batch.max(1)];
        match router.submit(model, probe).recv() {
            Ok(Ok(_)) => println!("budget probe: burst of {} admitted", max_batch.max(1)),
            Ok(Err(e)) => println!("budget probe: refused — {e}"),
            Err(_) => eprintln!("budget probe: worker died"),
        }
    }
    let rejected = router.server(model).unwrap().metrics().snapshot().rejected;
    println!(
        "{ok}/{requests} ok in {:.3}s -> {:.1} req/s | p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | mean batch {:.2} (max {}), mean queue {:.2} ms | {} rejected",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64(),
        snap.p50_us as f64 / 1000.0,
        snap.p95_us as f64 / 1000.0,
        snap.p99_us as f64 / 1000.0,
        snap.mean_batch,
        snap.max_batch_seen,
        snap.mean_queue_us as f64 / 1000.0,
        rejected,
    );
    if continuous {
        println!(
            "continuous: {} request(s) admitted into in-flight decode loops \
             (mean {:.2} lane(s) live at retirement, max {})",
            snap.continuous_admissions, snap.mean_batch, snap.max_batch_seen,
        );
    }
    // The spill story, only when a tier exists: how often the elastic
    // admission fired, what eviction bought (compressed footprint) and
    // what reloads cost (stall tail). Refuse-default runs print nothing.
    if spilling {
        let tier = service.pool().spill_tier().expect("spill tier configured above");
        let s = tier.stats();
        let admissions = router.server(model).unwrap().metrics().snapshot().spill_admissions;
        let ratio = if s.bytes_after == 0 {
            1.0
        } else {
            s.bytes_before as f64 / s.bytes_after as f64
        };
        println!(
            "spill: {admissions} over-budget admission(s); {} eviction(s) / {} reload(s), \
             {ratio:.2}x compressed ({:.1} -> {:.1} KiB), reload stall p99 {} us",
            s.evictions,
            s.reloads,
            s.bytes_before as f64 / 1024.0,
            s.bytes_after as f64 / 1024.0,
            s.stall_p99_us,
        );
    }
    router.shutdown();
    let st = service.stats();
    // Report the arena at the engine's batch cap — what the serving box
    // actually hosts — not the batch-1 plan. For dynamic serving that is
    // the worst-wave multi-pass peak.
    let at_max = req.with_batch(max_batch.max(1));
    let (planned_max, waves) = match &decode {
        // Paged serving hosts the prefix plan plus the tail's block
        // footprint — what the box actually keeps resident.
        Some((_, dyn_recs)) if paged => {
            let prefix = service
                .plan_dynamic(dyn_recs, &at_max.with_dynamic(DynamicMode::Resolved(0)))
                .map_err(|e| e.to_string())?;
            let full = service
                .plan_dynamic(dyn_recs, &at_max.with_dynamic(DynamicMode::FullyResolved))
                .map_err(|e| e.to_string())?;
            let tail = dyn_recs.tail_block_demand(BLOCK_WORDS) * BLOCK_WORDS * 4;
            (prefix.peak + tail, full.passes)
        }
        Some((_, dyn_recs)) => {
            let mp = service
                .plan_dynamic(dyn_recs, &at_max.with_dynamic(DynamicMode::FullyResolved))
                .map_err(|e| e.to_string())?;
            (mp.peak, mp.passes)
        }
        None => (
            service.plan(&recs, &at_max).map_err(|e| e.to_string())?.total_size(),
            0,
        ),
    };
    let stats = ArenaStats::from_service(
        planned_max,
        recs.naive_total() * max_batch.max(1),
        req.strategy(),
        st,
    );
    let stats = if waves > 0 { stats.with_waves(waves, 0) } else { stats };
    // The paged segment reports the shared block pool's high-water mark —
    // live counters from the pool the worker's engine paged through.
    let stats = if paged {
        let blocks = service.pool().blocks();
        stats.with_paged(blocks.peak_blocks() as u64, blocks.fragmentation())
    } else {
        stats
    };
    // The order segment is reported only when an order was actually
    // applied — plain serving keeps the PR-2 stats line unchanged.
    let stats = if order.is_natural() {
        stats
    } else {
        stats.with_order(
            applied.key(),
            applied.natural_breadth,
            applied.order_breadth,
        )
    };
    // The exec segment reports the configured worker count and the graph's
    // dataflow depth; the live ops-parallel counter stays inside the worker
    // thread's engine (see `ExecutorEngine::arena_stats`), so the CLI line
    // reports the shape, not the counter.
    let stats = if threads > 1 {
        let levels = tensorarena::graph::topo_levels(&g).map_or(0, |ls| ls.len());
        stats.with_threads(threads, levels, 0)
    } else {
        stats
    };
    // The dtype segment is reported only for quantized serving — f32
    // clears the field, keeping the plain stats line unchanged.
    let stats = stats.with_dtype(dtype);
    println!(
        "at max batch {}: {}",
        max_batch.max(1),
        coordinator::render_arena_stats(&stats)
    );
    if let Some(dir) = plan_dir {
        let report = service
            .persist_dir(Path::new(dir))
            .map_err(|e| format!("persisting to {dir}: {e}"))?;
        println!(
            "plan dir {dir}: persisted {} plan(s) for the next start",
            report.written
        );
    }
    Ok(())
}

/// Load the AOT artifacts, spin up the coordinator, fire a closed-loop
/// request storm, report latency/throughput and the planner's arena story.
/// Since the `PlanRequest` redesign the PJRT engine takes the shared
/// [`PlanService`] plus a typed request — its `planned_peak` /
/// `max_servable_batch` resolve through the same cache as the pure-Rust
/// path, so `--mem-budget` admission works here too.
#[cfg(feature = "pjrt")]
fn serve_bench(
    dir: &str,
    strategy: &str,
    requests: usize,
    max_batch: usize,
    wait_ms: u64,
    mem_budget: Option<usize>,
) -> anyhow::Result<()> {
    use tensorarena::coordinator::engine::PjrtEngine;
    use tensorarena::runtime::{Runtime, VariantSet};

    // Probe availability up front for a friendly error (the serving engine
    // itself is built on the worker thread — PJRT handles are not Send).
    {
        let rt = Runtime::cpu()?;
        let (platform, devs) = rt.platform();
        println!("PJRT platform={platform} devices={devs}");
        let found = Runtime::discover_variants(std::path::Path::new(dir), "model")?;
        println!(
            "found {} variants (batches {:?})",
            found.len(),
            found.iter().map(|(b, _)| *b).collect::<Vec<_>>()
        );
    }
    // One shared service + typed request: the L2 graph's rust twin is the
    // planner-managed working set behind the compiled executables.
    let service = PlanService::shared();
    let req = PlanRequest::new()
        .with_strategy(strategy)
        .map_err(anyhow::Error::msg)?
        .with_batch(max_batch.max(1));
    let twin = models::l2_cnn();
    let recs = UsageRecords::from_graph(&twin);
    let plan = service.plan(&recs, &req.with_batch(1)).map_err(anyhow::Error::msg)?;
    println!(
        "L2 twin arena: {:.1} KiB planned vs {:.1} KiB naive ({:.1}x)",
        plan.total_size() as f64 / 1024.0,
        recs.naive_total() as f64 / 1024.0,
        recs.naive_total() as f64 / plan.total_size().max(1) as f64,
    );
    if let Some(budget) = mem_budget {
        let cap = service
            .max_servable_batch(&recs, &req, budget)
            .map_err(anyhow::Error::msg)?;
        println!(
            "mem budget {:.1} KiB: max servable batch {cap}{}",
            budget as f64 / 1024.0,
            if cap < max_batch { " (clamping the batcher)" } else { "" },
        );
    }

    let mut router = Router::new();
    let dir_owned = dir.to_string();
    let service_for_engine = Arc::clone(&service);
    let recs_for_engine = recs.clone();
    router.register(
        "cnn",
        move || {
            let rt = Runtime::cpu().expect("PJRT client");
            let variants =
                VariantSet::load(&rt, std::path::Path::new(&dir_owned), "model", &[32, 32, 3], 10)
                    .expect("load artifacts");
            Box::new(
                PjrtEngine::with_request(variants, service_for_engine, recs_for_engine, &req)
                    .expect("twin plan"),
            )
        },
        BatchPolicy {
            max_batch,
            max_wait: std::time::Duration::from_millis(wait_ms),
            mem_budget,
            ..BatchPolicy::default()
        },
    )?;

    let mut rng = SplitMix64::new(42);
    let mut input = vec![0f32; 32 * 32 * 3];
    let t0 = std::time::Instant::now();
    let mut pending = Vec::with_capacity(requests);
    for _ in 0..requests {
        rng.fill_f32(&mut input, 1.0);
        pending.push(router.submit("cnn", input.clone()));
    }
    let mut ok = 0;
    for rx in pending {
        match rx.recv() {
            Ok(Ok(out)) => {
                assert_eq!(out.len(), 10);
                ok += 1;
            }
            Ok(Err(e)) => eprintln!("request error: {e}"),
            Err(_) => eprintln!("worker died"),
        }
    }
    let wall = t0.elapsed();
    let snap = router.server("cnn").unwrap().metrics().snapshot();
    println!(
        "{ok}/{requests} ok in {:.3}s -> {:.1} req/s | p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | mean batch {:.2}, mean queue {:.2} ms",
        wall.as_secs_f64(),
        ok as f64 / wall.as_secs_f64(),
        snap.p50_us as f64 / 1000.0,
        snap.p95_us as f64 / 1000.0,
        snap.p99_us as f64 / 1000.0,
        snap.mean_batch,
        snap.mean_queue_us as f64 / 1000.0,
    );
    router.shutdown();
    // The shared-cache story the snapshot path could never tell: the AOT
    // engine's budget probes and batch plans all landed in one PlanService.
    let stats = ArenaStats::from_service(
        service.plan(&recs, &req).map_err(anyhow::Error::msg)?.total_size(),
        recs.naive_total() * max_batch.max(1),
        req.strategy(),
        service.stats(),
    );
    println!(
        "at max batch {}: {}",
        max_batch.max(1),
        coordinator::render_arena_stats(&stats)
    );
    Ok(())
}
