//! The spill tier: evicted arena buffers, compressed in memory, optionally
//! backed by a disk directory — the elastic layer between "resident" and
//! "refused".
//!
//! The paper plans offsets assuming every live tensor fits one physical
//! arena; production systems treat memory as a hierarchy and move cold
//! bytes down it. [`SpillTier`] is that hierarchy's middle and bottom:
//! [`crate::arena::ArenaPool`] evicts cold idle shelf buffers into the
//! tier when residency exceeds a configured watermark, and reloads them on
//! demand when an acquisition misses the resident shelves. Admission
//! (`coordinator::batcher`) can then treat the budget boundary as elastic:
//! a request that exceeds the resident budget but fits
//! `resident + spill capacity` is served by demand-reloading instead of
//! being refused ([`crate::coordinator::AdmissionOutcome::Spill`]).
//!
//! # The codec
//!
//! Dependency-free and byte-oriented over the f32 word stream (every
//! arena buffer is a `Vec<f32>` of 64-byte-aligned regions): each word's
//! bit pattern is XOR-delta'd against its predecessor, then the delta
//! stream is zero-run encoded as `(zero_run, literal_run)` LEB128 varint
//! token pairs followed by the literal words' little-endian bytes. Runs of
//! equal words (zeroed regions, constant fills) collapse to a few bytes;
//! incompressible streams fall back to a stored-raw encoding, so the
//! output is **never larger than `1 + 4 × words` bytes** (one tag byte
//! plus the raw stream) — the invariant the codec property tests pin. The
//! transform is bit-exact: NaN payloads and signed zeros round-trip
//! unchanged.
//!
//! # The disk directory
//!
//! With a directory attached ([`SpillTier::with_dir`], `serve
//! --spill-dir`), every spilled entry is also persisted as a
//! checksummed, self-describing file, written atomically (dot-prefixed
//! per-process `.tmp` sibling + rename, like the plan directory) with the
//! `.tmp` removed on every error path. [`SpillTier::load_dir`] re-adopts a
//! directory's entries on restart, *skipping* — never serving, never
//! crashing on — anything truncated, bit-flipped, wrong-length, or written
//! by a different format version, with one typed counter per failure class
//! ([`SpillDirReport`]).

use crate::coordinator::metrics::Reservoir;
use crate::planner::serialize::fnv1a;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// First byte of a stored-raw codec stream (compression didn't pay).
const TAG_RAW: u8 = 0;
/// First byte of a zero-run + XOR-delta coded stream.
const TAG_CODED: u8 = 1;

/// Decoder bound on the word count a coded stream may claim: a corrupt
/// varint must fail the decode, not balloon into an allocation. 2^28 words
/// is a 1 GiB buffer — far beyond any arena this crate plans.
const MAX_SPILL_WORDS: usize = 1 << 28;

/// First line of every spill-tier disk entry; bump on format changes so
/// old readers skip new files (and vice versa) as `stale_format`.
const SPILL_MAGIC: &str = "tensorarena-spill v1";

/// Compress an f32 word stream: XOR-delta over the bit patterns, zero-run
/// encoded, with a stored-raw fallback when the coded form would be larger.
/// The result is never longer than `1 + 4 * words.len()` bytes and
/// round-trips bit-exactly through [`decompress`].
pub fn compress(words: &[f32]) -> Vec<u8> {
    let raw_len = 1 + words.len() * 4;
    let mut out = Vec::with_capacity(raw_len.min(256));
    out.push(TAG_CODED);
    let mut deltas = Vec::with_capacity(words.len());
    let mut prev = 0u32;
    for w in words {
        let bits = w.to_bits();
        deltas.push(bits ^ prev);
        prev = bits;
    }
    let mut i = 0;
    while i < deltas.len() {
        let zero_start = i;
        while i < deltas.len() && deltas[i] == 0 {
            i += 1;
        }
        let lit_start = i;
        while i < deltas.len() && deltas[i] != 0 {
            i += 1;
        }
        push_varint(&mut out, zero_start.abs_diff(lit_start));
        push_varint(&mut out, lit_start.abs_diff(i));
        for d in &deltas[lit_start..i] {
            out.extend_from_slice(&d.to_le_bytes());
        }
        // Early out: already at least raw-sized, the fallback will win.
        if out.len() >= raw_len {
            break;
        }
    }
    if out.len() >= raw_len {
        out.clear();
        out.push(TAG_RAW);
        for w in words {
            out.extend_from_slice(&w.to_bits().to_le_bytes());
        }
    }
    out
}

/// Decompress a [`compress`]-produced stream back into f32 words. Returns
/// `None` — never panics, never a partial buffer — on any malformation:
/// unknown tag, truncated literals, trailing garbage, non-word-aligned raw
/// payload, or a varint claiming an absurd length.
pub fn decompress(bytes: &[u8]) -> Option<Vec<f32>> {
    let (&tag, rest) = bytes.split_first()?;
    match tag {
        TAG_RAW => {
            if rest.len() % 4 != 0 {
                return None;
            }
            Some(
                rest.chunks_exact(4)
                    .map(|c| f32::from_bits(u32::from_le_bytes([c[0], c[1], c[2], c[3]])))
                    .collect(),
            )
        }
        TAG_CODED => {
            let mut deltas: Vec<u32> = Vec::new();
            let mut i = 0;
            while i < rest.len() {
                let zeros = read_varint(rest, &mut i)?;
                let lits = read_varint(rest, &mut i)?;
                let total = deltas.len().checked_add(zeros)?.checked_add(lits)?;
                if total > MAX_SPILL_WORDS {
                    return None;
                }
                deltas.resize(deltas.len() + zeros, 0);
                for _ in 0..lits {
                    let chunk = rest.get(i..i + 4)?;
                    deltas.push(u32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]));
                    i += 4;
                }
            }
            let mut prev = 0u32;
            Some(
                deltas
                    .into_iter()
                    .map(|d| {
                        prev ^= d;
                        f32::from_bits(prev)
                    })
                    .collect(),
            )
        }
        _ => None,
    }
}

fn push_varint(out: &mut Vec<u8>, mut v: usize) {
    loop {
        let byte = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(byte);
            return;
        }
        out.push(byte | 0x80);
    }
}

fn read_varint(bytes: &[u8], i: &mut usize) -> Option<usize> {
    let mut v: usize = 0;
    let mut shift = 0u32;
    loop {
        let &byte = bytes.get(*i)?;
        *i += 1;
        if shift >= usize::BITS {
            return None;
        }
        v |= ((byte & 0x7f) as usize).checked_shl(shift)?;
        if byte & 0x80 == 0 {
            return Some(v);
        }
        shift += 7;
    }
}

/// One compressed evicted buffer.
struct SpillEntry {
    id: u64,
    /// Original (uncompressed) word count.
    words: usize,
    /// Codec output ([`compress`]).
    bytes: Vec<u8>,
}

struct TierInner {
    /// Oldest first; eviction appends, reload removes its best fit.
    entries: Vec<SpillEntry>,
    next_id: u64,
}

/// Point-in-time spill counters, the shape `PlanService::stats()` folds
/// into `ArenaStats` for the serving metrics line.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillStats {
    /// Buffers evicted into the tier.
    pub evictions: u64,
    /// Buffers reloaded (decompressed) out of the tier.
    pub reloads: u64,
    /// Raw bytes of everything evicted so far (before compression).
    pub bytes_before: u64,
    /// Stored bytes of everything evicted so far (after compression).
    pub bytes_after: u64,
    /// 99th-percentile reload stall, microseconds (reservoir-sampled).
    pub stall_p99_us: u64,
}

/// Typed per-failure-class counters from [`SpillTier::load_dir`]: damaged
/// disk entries are skipped and counted, mirroring the plan directory's
/// warm-start report, and can never corrupt a reload.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct SpillDirReport {
    /// Entries adopted into the tier.
    pub loaded: usize,
    /// Files cut short of their declared payload (or of the header).
    pub skipped_truncated: usize,
    /// Files whose first line is not this build's format version.
    pub skipped_stale_format: usize,
    /// Files whose payload or decoded stream disagrees with the declared
    /// lengths (e.g. trailing bytes, a word count that doesn't decode).
    pub skipped_wrong_length: usize,
    /// Checksum mismatches, unparseable headers, undecodable payloads.
    pub skipped_corrupt: usize,
}

impl SpillDirReport {
    /// Total entries skipped, over every failure class.
    pub fn skipped(&self) -> usize {
        self.skipped_truncated
            + self.skipped_stale_format
            + self.skipped_wrong_length
            + self.skipped_corrupt
    }
}

/// The compressed spill store behind [`crate::arena::ArenaPool`], with an
/// optional disk directory behind *it* — the three-tier lifecycle is
/// resident shelf → compressed entry → disk file (see
/// `docs/ARCHITECTURE.md` §3).
///
/// All methods take `&self`: the tier is shared (`Arc`) between the pool,
/// the serving engines, and the stats path.
pub struct SpillTier {
    inner: Mutex<TierInner>,
    dir: Option<PathBuf>,
    /// Elastic capacity admission charges against (`resident + spillable`);
    /// effectively unbounded by default.
    capacity_bytes: AtomicUsize,
    evictions: AtomicU64,
    reloads: AtomicU64,
    bytes_before: AtomicU64,
    bytes_after: AtomicU64,
    disk_write_errors: AtomicU64,
    /// Reload-stall samples, microseconds — the same bounded reservoir the
    /// serving metrics keep latencies in.
    stalls: Mutex<Reservoir>,
}

impl Default for SpillTier {
    fn default() -> Self {
        Self::new()
    }
}

impl SpillTier {
    /// An in-memory-only tier (no disk directory).
    pub fn new() -> Self {
        SpillTier {
            inner: Mutex::new(TierInner { entries: Vec::new(), next_id: 0 }),
            dir: None,
            capacity_bytes: AtomicUsize::new(usize::MAX),
            evictions: AtomicU64::new(0),
            reloads: AtomicU64::new(0),
            bytes_before: AtomicU64::new(0),
            bytes_after: AtomicU64::new(0),
            disk_write_errors: AtomicU64::new(0),
            stalls: Mutex::new(Reservoir::default()),
        }
    }

    /// A tier persisting every spilled entry into `dir` (created if
    /// absent). Call [`Self::load_dir`] to adopt entries a previous
    /// process left there.
    pub fn with_dir(dir: impl Into<PathBuf>) -> std::io::Result<Self> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        Ok(SpillTier { dir: Some(dir), ..Self::new() })
    }

    /// The attached disk directory, if any.
    pub fn dir(&self) -> Option<&Path> {
        self.dir.as_deref()
    }

    /// The elastic capacity admission may charge against (bytes).
    /// Unbounded (`usize::MAX`) unless [`Self::set_capacity_bytes`] was
    /// called.
    pub fn capacity_bytes(&self) -> usize {
        self.capacity_bytes.load(Ordering::Relaxed)
    }

    /// Bound the capacity admission charges against. Does not evict: the
    /// bound only changes future `AdmissionOutcome::Spill` decisions.
    pub fn set_capacity_bytes(&self, bytes: usize) {
        self.capacity_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Evict a buffer into the tier: compress, count, persist (when a
    /// directory is attached), and store. Disk failures are counted
    /// ([`Self::disk_write_errors`]) and never lose the entry — the
    /// in-memory compressed copy stays authoritative.
    pub fn spill(&self, buf: Vec<f32>) {
        let words = buf.len();
        let bytes = compress(&buf);
        drop(buf);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        self.bytes_before.fetch_add(words as u64 * 4, Ordering::Relaxed);
        self.bytes_after.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let mut inner = self.inner.lock().unwrap();
        let id = inner.next_id;
        inner.next_id += 1;
        if let Some(dir) = &self.dir {
            if persist_entry(dir, id, words, &bytes).is_err() {
                self.disk_write_errors.fetch_add(1, Ordering::Relaxed);
            }
        }
        inner.entries.push(SpillEntry { id, words, bytes });
    }

    /// Reload the smallest entry covering `words`, probing the request's
    /// size class and the one above (the same fit policy as the resident
    /// shelves). Returns the decompressed buffer (length ≥ `words`) and
    /// removes the entry — and its disk file — from the tier. The stall
    /// (search + decompress) is reservoir-sampled for the metrics line.
    pub fn reload(&self, words: usize) -> Option<Vec<f32>> {
        let t0 = Instant::now();
        let class = class_of(words.max(1));
        let (id, bytes, entry_words) = {
            let mut inner = self.inner.lock().unwrap();
            let fit = inner
                .entries
                .iter()
                .enumerate()
                .filter(|(_, e)| {
                    let c = class_of(e.words.max(1));
                    e.words >= words && (c == class || c == class + 1)
                })
                .min_by_key(|&(_, e)| e.words)
                .map(|(i, _)| i)?;
            let e = inner.entries.swap_remove(fit);
            (e.id, e.bytes, e.words)
        };
        // The in-memory copy came out of `compress`, so this cannot fail;
        // `expect` (not unwrap) documents the invariant.
        let buf = decompress(&bytes).expect("in-memory spill entries round-trip");
        debug_assert_eq!(buf.len(), entry_words);
        self.reloads.fetch_add(1, Ordering::Relaxed);
        if let Some(dir) = &self.dir {
            let _ = std::fs::remove_file(dir.join(entry_file_name(id, entry_words)));
        }
        let stall = t0.elapsed().as_micros() as u64;
        self.stalls.lock().unwrap().record(stall);
        Some(buf)
    }

    /// Adopt the entries a previous process persisted into the attached
    /// directory, skipping damage with one typed counter per failure
    /// class. A no-op `Ok` with an all-zero report when no directory is
    /// attached.
    pub fn load_dir(&self) -> std::io::Result<SpillDirReport> {
        let mut report = SpillDirReport::default();
        let Some(dir) = &self.dir else {
            return Ok(report);
        };
        let mut names: Vec<PathBuf> = std::fs::read_dir(dir)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| p.extension().is_some_and(|x| x == "spill"))
            .collect();
        names.sort();
        for path in names {
            match parse_entry_file(&path) {
                Ok((words, bytes)) => {
                    let mut inner = self.inner.lock().unwrap();
                    let id = inner.next_id;
                    inner.next_id += 1;
                    // Re-key the adopted entry under this process's id
                    // space; the stale file name is removed so a reload
                    // never leaves an orphan behind.
                    let persisted = persist_entry(dir, id, words, &bytes).is_ok();
                    if persisted && path != dir.join(entry_file_name(id, words)) {
                        let _ = std::fs::remove_file(&path);
                    }
                    inner.entries.push(SpillEntry { id, words, bytes });
                    report.loaded += 1;
                }
                Err(EntryDamage::Truncated) => report.skipped_truncated += 1,
                Err(EntryDamage::StaleFormat) => report.skipped_stale_format += 1,
                Err(EntryDamage::WrongLength) => report.skipped_wrong_length += 1,
                Err(EntryDamage::Corrupt) => report.skipped_corrupt += 1,
            }
        }
        Ok(report)
    }

    /// Entries currently held.
    pub fn entries(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    /// Raw (uncompressed) bytes of the entries currently held — what the
    /// tier could hand back to the resident shelves on demand.
    pub fn resident_raw_bytes(&self) -> usize {
        self.inner.lock().unwrap().entries.iter().map(|e| e.words * 4).sum()
    }

    /// Stored (compressed) bytes of the entries currently held.
    pub fn stored_bytes(&self) -> usize {
        self.inner.lock().unwrap().entries.iter().map(|e| e.bytes.len()).sum()
    }

    /// Buffers evicted into the tier so far.
    pub fn evictions(&self) -> u64 {
        self.evictions.load(Ordering::Relaxed)
    }

    /// Buffers reloaded out of the tier so far.
    pub fn reloads(&self) -> u64 {
        self.reloads.load(Ordering::Relaxed)
    }

    /// Cumulative raw bytes evicted (before compression).
    pub fn bytes_before(&self) -> u64 {
        self.bytes_before.load(Ordering::Relaxed)
    }

    /// Cumulative stored bytes evicted (after compression).
    pub fn bytes_after(&self) -> u64 {
        self.bytes_after.load(Ordering::Relaxed)
    }

    /// Failed disk writes (the in-memory entry survives each one).
    pub fn disk_write_errors(&self) -> u64 {
        self.disk_write_errors.load(Ordering::Relaxed)
    }

    /// Cumulative compression ratio (raw / stored); 1.0 with no traffic.
    pub fn compression_ratio(&self) -> f64 {
        let after = self.bytes_after();
        if after == 0 {
            1.0
        } else {
            self.bytes_before() as f64 / after as f64
        }
    }

    /// 99th-percentile reload stall, microseconds.
    pub fn stall_p99_us(&self) -> u64 {
        self.stalls.lock().unwrap().percentile(0.99)
    }

    /// Everything the serving metrics line needs, in one snapshot.
    pub fn stats(&self) -> SpillStats {
        SpillStats {
            evictions: self.evictions(),
            reloads: self.reloads(),
            bytes_before: self.bytes_before(),
            bytes_after: self.bytes_after(),
            stall_p99_us: self.stall_p99_us(),
        }
    }
}

/// Size class of a word count: floor of log2 (the `ArenaPool` classing).
fn class_of(words: usize) -> usize {
    (usize::BITS - 1 - words.max(1).leading_zeros()) as usize
}

fn entry_file_name(id: u64, words: usize) -> String {
    format!("spill-{id:016x}-w{words}.spill")
}

/// Write one entry atomically: dot-prefixed per-process `.tmp` sibling,
/// then rename — and remove the `.tmp` on *every* error path, so a failed
/// write never leaves a partial file for [`SpillTier::load_dir`] to trip
/// on.
fn persist_entry(dir: &Path, id: u64, words: usize, bytes: &[u8]) -> std::io::Result<()> {
    let name = entry_file_name(id, words);
    let tmp = dir.join(format!(".{name}.{}.tmp", std::process::id()));
    let mut payload = Vec::with_capacity(SPILL_MAGIC.len() + 64 + bytes.len());
    payload.extend_from_slice(SPILL_MAGIC.as_bytes());
    payload.push(b'\n');
    payload.extend_from_slice(
        format!("words {words} bytes {} checksum {:016x}\n", bytes.len(), fnv1a(bytes)).as_bytes(),
    );
    payload.extend_from_slice(bytes);
    let written = std::fs::write(&tmp, &payload)
        .and_then(|()| std::fs::rename(&tmp, dir.join(&name)));
    if written.is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
    written
}

enum EntryDamage {
    Truncated,
    StaleFormat,
    WrongLength,
    Corrupt,
}

/// Parse and verify one on-disk entry into `(words, codec bytes)`.
fn parse_entry_file(path: &Path) -> Result<(usize, Vec<u8>), EntryDamage> {
    let data = std::fs::read(path).map_err(|_| EntryDamage::Corrupt)?;
    let magic_end = data.iter().position(|&b| b == b'\n').ok_or(EntryDamage::Truncated)?;
    if &data[..magic_end] != SPILL_MAGIC.as_bytes() {
        return Err(EntryDamage::StaleFormat);
    }
    let rest = &data[magic_end + 1..];
    let header_end = rest.iter().position(|&b| b == b'\n').ok_or(EntryDamage::Truncated)?;
    let header = std::str::from_utf8(&rest[..header_end]).map_err(|_| EntryDamage::Corrupt)?;
    let tok: Vec<&str> = header.split_whitespace().collect();
    let (words, declared, sum) = match tok.as_slice() {
        ["words", w, "bytes", b, "checksum", c] => (
            w.parse::<usize>().map_err(|_| EntryDamage::Corrupt)?,
            b.parse::<usize>().map_err(|_| EntryDamage::Corrupt)?,
            u64::from_str_radix(c, 16).map_err(|_| EntryDamage::Corrupt)?,
        ),
        _ => return Err(EntryDamage::Corrupt),
    };
    let payload = &rest[header_end + 1..];
    if payload.len() < declared {
        return Err(EntryDamage::Truncated);
    }
    if payload.len() > declared {
        return Err(EntryDamage::WrongLength);
    }
    if fnv1a(payload) != sum {
        return Err(EntryDamage::Corrupt);
    }
    let decoded = decompress(payload).ok_or(EntryDamage::Corrupt)?;
    if decoded.len() != words {
        return Err(EntryDamage::WrongLength);
    }
    Ok((words, payload.to_vec()))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(words: &[f32]) {
        let c = compress(words);
        assert!(
            c.len() <= 1 + words.len() * 4,
            "compressed {} > stored-raw {} for {} words",
            c.len(),
            1 + words.len() * 4,
            words.len()
        );
        let back = decompress(&c).expect("well-formed stream");
        assert_eq!(back.len(), words.len());
        for (a, b) in words.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "codec must be bit-exact");
        }
    }

    #[test]
    fn codec_roundtrips_representative_streams() {
        roundtrip(&[]);
        roundtrip(&[0.0; 1000]);
        roundtrip(&[3.25; 577]);
        roundtrip(&[f32::NAN, -0.0, f32::INFINITY, f32::MIN_POSITIVE, 1.5e-40]);
        let ramp: Vec<f32> = (0..300).map(|i| i as f32 * 0.37).collect();
        roundtrip(&ramp);
        let mut mixed = vec![0.0f32; 64];
        mixed.extend((0..17).map(|i| (i * 2654435761u32 % 977) as f32));
        mixed.extend(vec![7.0f32; 200]);
        roundtrip(&mixed);
    }

    #[test]
    fn zero_heavy_streams_actually_shrink() {
        let c = compress(&[0.0f32; 4096]);
        assert!(c.len() < 16, "an all-zero buffer must collapse, got {} bytes", c.len());
        let c = compress(&[1.25f32; 4096]);
        assert!(c.len() < 32, "a constant buffer must collapse, got {} bytes", c.len());
    }

    #[test]
    fn decompress_rejects_malformed_streams() {
        assert_eq!(decompress(&[]), None, "empty stream has no tag");
        assert_eq!(decompress(&[9, 1, 2, 3]), None, "unknown tag");
        assert_eq!(decompress(&[TAG_RAW, 1, 2, 3]), None, "raw payload not word-aligned");
        // Truncated literal run: claims one literal, carries two bytes.
        assert_eq!(decompress(&[TAG_CODED, 0, 1, 0xaa, 0xbb]), None);
        // A varint claiming an absurd zero run must fail, not allocate.
        let mut huge = vec![TAG_CODED];
        push_varint(&mut huge, usize::MAX / 2);
        push_varint(&mut huge, 0);
        assert_eq!(decompress(&huge), None);
    }

    #[test]
    fn tier_spills_and_reloads_best_fit() {
        let tier = SpillTier::new();
        tier.spill(vec![1.0; 300]);
        tier.spill(vec![2.0; 280]);
        tier.spill(vec![3.0; 600]);
        assert_eq!(tier.evictions(), 3);
        assert_eq!(tier.entries(), 3);
        // Best fit within the class: 280 covers a 270-word request even
        // though 300 was spilled first.
        let got = tier.reload(270).expect("a fitting entry");
        assert_eq!(got.len(), 280);
        assert!(got.iter().all(|&v| v == 2.0), "reload must be bit-exact");
        assert_eq!(tier.reloads(), 1);
        // Nothing in class 9..=10 covers 700 words; the 600-word entry is
        // class 9 but too small, so the miss is a None, not a panic.
        assert!(tier.reload(700).is_none());
        assert_eq!(tier.entries(), 2);
        assert!(tier.bytes_before() >= tier.bytes_after(), "codec never inflates");
    }
}
