//! Block-granular (paged) arena storage for decode tails.
//!
//! The resident arena sizes dynamic serving at the **worst-wave peak**
//! (`planner::dynamic`): static preallocation, with exactly the waste the
//! shared-object taxonomy warns about — a short decode tail strands memory
//! other in-flight requests could use. This module applies the
//! PagedAttention idea (OS-style virtual memory for tensors): decode-tail
//! records map their regions onto lists of fixed-size blocks drawn from a
//! [`BlockPool`] shared across executors through the [`ArenaPool`] handle,
//! so tail tensors allocate incrementally at wave boundaries and freed
//! blocks are *immediately* servable to other requests.
//!
//! Two layers:
//!
//! - [`BlockPool`] — the shared freelist of fixed [`BLOCK_WORDS`]-word
//!   blocks, with reuse/allocation/drop counters mirroring [`ArenaPool`]
//!   plus live/peak gauges that make block-level [`fragmentation`]
//!   observable in serving metrics.
//! - [`PagedArena`] — a per-executor mapping from record ids to block
//!   lists, with `gather`/`scatter` copies in and out of a contiguous
//!   scratch stripe so kernels run unchanged (and bit-identically) on
//!   paged tensors.
//!
//! [`fragmentation`]: BlockPool::fragmentation

use super::ArenaPool;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Words per block: 128 `f32` words = 512 bytes, a multiple of the crate's
/// 64-byte alignment quantum, so every block boundary is itself 64-byte
/// aligned.
pub const BLOCK_WORDS: usize = 128;

/// Default for the most free blocks the pool retains; beyond the cap,
/// released blocks are dropped (and counted) to bound pool memory under
/// churn. Tunable per pool with [`BlockPool::set_shelf_cap`] (CLI:
/// `serve --block-cap`) so the freelist bound and the spill watermark can
/// be tuned together.
pub const DEFAULT_BLOCK_SHELF_CAP: usize = 1024;

/// Gauges guarded by the pool mutex: the freelist plus the live/peak
/// accounting that fragmentation is computed from.
#[derive(Default)]
struct PoolInner {
    /// Free blocks, each exactly [`BLOCK_WORDS`] long.
    free: Vec<Vec<f32>>,
    /// Blocks currently mapped by some [`PagedArena`].
    in_use: usize,
    /// Payload words currently mapped (requested sizes, not block-rounded).
    live_words: usize,
    /// High-water mark of `in_use`.
    peak_blocks: usize,
    /// `live_words` snapshot taken when `peak_blocks` was last raised.
    words_at_peak: usize,
}

/// Shared freelist of fixed 64-byte-aligned blocks for paged decode-tail
/// storage. One `BlockPool` lives inside every [`ArenaPool`]
/// ([`ArenaPool::blocks`]), so executors sharing an arena pool — the
/// serving coordinator's normal state — automatically share tail blocks:
/// a block freed by one request's dying tail tensor is immediately
/// servable to any other request on the same pool.
pub struct BlockPool {
    inner: Mutex<PoolInner>,
    reused: AtomicU64,
    allocated: AtomicU64,
    dropped: AtomicU64,
    /// Freelist retention cap ([`DEFAULT_BLOCK_SHELF_CAP`] unless tuned).
    shelf_cap: AtomicUsize,
}

impl Default for BlockPool {
    fn default() -> Self {
        BlockPool {
            inner: Mutex::default(),
            reused: AtomicU64::new(0),
            allocated: AtomicU64::new(0),
            dropped: AtomicU64::new(0),
            shelf_cap: AtomicUsize::new(DEFAULT_BLOCK_SHELF_CAP),
        }
    }
}

impl BlockPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// The current freelist retention cap.
    pub fn shelf_cap(&self) -> usize {
        self.shelf_cap.load(Ordering::Relaxed)
    }

    /// Tune the freelist retention cap. Applies to future releases only;
    /// blocks already shelved are not trimmed, and drops keep counting.
    pub fn set_shelf_cap(&self, cap: usize) {
        self.shelf_cap.store(cap, Ordering::Relaxed);
    }

    /// Acquire enough blocks to back `words` payload words
    /// (`ceil(words / BLOCK_WORDS)` blocks), each zeroed, recycling free
    /// blocks before allocating. Returns an empty list for `words == 0`.
    pub fn acquire_region(&self, words: usize) -> Vec<Vec<f32>> {
        if words == 0 {
            return Vec::new();
        }
        let n = words.div_ceil(BLOCK_WORDS);
        let mut blocks = Vec::with_capacity(n);
        let mut inner = self.inner.lock().unwrap();
        for _ in 0..n {
            if let Some(mut b) = inner.free.pop() {
                self.reused.fetch_add(1, Ordering::Relaxed);
                b.fill(0.0);
                blocks.push(b);
            } else {
                self.allocated.fetch_add(1, Ordering::Relaxed);
                blocks.push(vec![0f32; BLOCK_WORDS]);
            }
        }
        inner.in_use += n;
        inner.live_words += words;
        if inner.in_use > inner.peak_blocks {
            inner.peak_blocks = inner.in_use;
            inner.words_at_peak = inner.live_words;
        }
        blocks
    }

    /// Return a region's blocks to the freelist. `words` must be the
    /// payload size the region was acquired for (the gauges are kept in
    /// the same units as [`Self::acquire_region`]). Blocks past the
    /// retention cap are dropped and counted.
    pub fn release_region(&self, blocks: Vec<Vec<f32>>, words: usize) {
        if blocks.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.in_use = inner.in_use.saturating_sub(blocks.len());
        inner.live_words = inner.live_words.saturating_sub(words);
        let cap = self.shelf_cap();
        for b in blocks {
            if inner.free.len() < cap {
                inner.free.push(b);
            } else {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    /// Blocks currently mapped across every arena sharing this pool.
    pub fn blocks_in_use(&self) -> usize {
        self.inner.lock().unwrap().in_use
    }

    /// High-water mark of mapped blocks — the paged analogue of the
    /// resident arena's planned peak; `blocks × BLOCK_WORDS × 4` bytes is
    /// what budget admission charges the decode tail.
    pub fn peak_blocks(&self) -> usize {
        self.inner.lock().unwrap().peak_blocks
    }

    /// Internal fragmentation at the block high-water mark: the fraction
    /// of peak block capacity that held no payload
    /// (`1 − live_words / (peak_blocks × BLOCK_WORDS)`, 0.0 when nothing
    /// was ever mapped). Only the last partial block of each region can
    /// waste words, so this is bounded by `regions / peak_blocks`.
    pub fn fragmentation(&self) -> f64 {
        let inner = self.inner.lock().unwrap();
        if inner.peak_blocks == 0 {
            return 0.0;
        }
        let capacity = (inner.peak_blocks * BLOCK_WORDS) as f64;
        (1.0 - inner.words_at_peak as f64 / capacity).max(0.0)
    }

    /// Blocks recycled from the freelist so far.
    pub fn reused(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }

    /// Blocks freshly allocated so far.
    pub fn allocated(&self) -> u64 {
        self.allocated.load(Ordering::Relaxed)
    }

    /// Blocks dropped at release because the freelist was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Free blocks currently shelved (tests and introspection).
    pub fn idle_blocks(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }
}

/// Per-executor mapping of record ids onto block lists from the shared
/// [`BlockPool`]. A record is *mapped* between its producing wave boundary
/// and its death; [`Self::unmap`] returns its blocks to the pool at once,
/// which is what makes a decode tail's memory servable to other requests
/// the moment each tail tensor dies instead of at end of batch.
pub struct PagedArena {
    pool: Arc<ArenaPool>,
    /// `maps[record]` — the record's block list while mapped.
    maps: Vec<Option<Vec<Vec<f32>>>>,
    /// Payload words per mapped record (requested, not block-rounded).
    words: Vec<usize>,
}

impl PagedArena {
    /// A paged arena over `num_records` record ids, drawing blocks from
    /// `pool`'s shared [`BlockPool`].
    pub fn new(pool: Arc<ArenaPool>, num_records: usize) -> Self {
        PagedArena {
            pool,
            maps: (0..num_records).map(|_| None).collect(),
            words: vec![0; num_records],
        }
    }

    /// True while `record` holds blocks.
    pub fn is_mapped(&self, record: usize) -> bool {
        self.maps[record].is_some()
    }

    /// Payload words of a mapped record (0 while unmapped).
    pub fn words_of(&self, record: usize) -> usize {
        self.words[record]
    }

    /// Map `record` onto freshly-acquired (zeroed) blocks backing `words`
    /// payload words. Panics if already mapped — a record maps exactly
    /// once per pass, at its producing wave boundary.
    pub fn map(&mut self, record: usize, words: usize) {
        assert!(self.maps[record].is_none(), "record {record} is already mapped");
        self.maps[record] = Some(self.pool.blocks().acquire_region(words));
        self.words[record] = words;
    }

    /// Unmap `record`, returning its blocks to the shared pool
    /// immediately. No-op if not mapped (a zero-word region maps to an
    /// empty block list, which releases trivially).
    pub fn unmap(&mut self, record: usize) {
        if let Some(blocks) = self.maps[record].take() {
            self.pool.blocks().release_region(blocks, self.words[record]);
            self.words[record] = 0;
        }
    }

    /// Copy a mapped record's payload into `dst` (`dst.len()` must equal
    /// the mapped word count). The contiguous copy is what lets kernels
    /// run unchanged — and bit-identically — on paged tensors.
    pub fn gather(&self, record: usize, dst: &mut [f32]) {
        let blocks = self.maps[record].as_ref().expect("gather of an unmapped record");
        assert_eq!(dst.len(), self.words[record], "gather size mismatch for record {record}");
        for (i, chunk) in dst.chunks_mut(BLOCK_WORDS).enumerate() {
            chunk.copy_from_slice(&blocks[i][..chunk.len()]);
        }
    }

    /// Copy `src` into a mapped record's blocks (`src.len()` must equal
    /// the mapped word count).
    pub fn scatter(&mut self, record: usize, src: &[f32]) {
        assert_eq!(src.len(), self.words[record], "scatter size mismatch for record {record}");
        let blocks = self.maps[record].as_mut().expect("scatter to an unmapped record");
        for (i, chunk) in src.chunks(BLOCK_WORDS).enumerate() {
            blocks[i][..chunk.len()].copy_from_slice(chunk);
        }
    }

    /// Unmap every record (defensive sweep; the per-step death hooks
    /// normally leave nothing behind).
    pub fn release_all(&mut self) {
        for r in 0..self.maps.len() {
            self.unmap(r);
        }
    }
}

impl Drop for PagedArena {
    fn drop(&mut self) {
        self.release_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_pool_rounds_up_and_recycles() {
        let pool = BlockPool::new();
        let region = pool.acquire_region(BLOCK_WORDS + 1);
        assert_eq!(region.len(), 2);
        assert!(region.iter().all(|b| b.len() == BLOCK_WORDS));
        assert_eq!((pool.allocated(), pool.reused()), (2, 0));
        assert_eq!(pool.blocks_in_use(), 2);
        pool.release_region(region, BLOCK_WORDS + 1);
        assert_eq!(pool.blocks_in_use(), 0);
        assert_eq!(pool.idle_blocks(), 2);
        // Freed blocks are immediately servable: the next region reuses
        // them, zeroed.
        let again = pool.acquire_region(2 * BLOCK_WORDS);
        assert_eq!((pool.allocated(), pool.reused()), (2, 2));
        assert!(again.iter().all(|b| b.iter().all(|&v| v == 0.0)));
        pool.release_region(again, 2 * BLOCK_WORDS);
    }

    #[test]
    fn fragmentation_is_measured_at_the_block_peak() {
        let pool = BlockPool::new();
        // One word in a whole block: (BLOCK_WORDS - 1) wasted at peak.
        let region = pool.acquire_region(1);
        assert_eq!(pool.peak_blocks(), 1);
        let expect = 1.0 - 1.0 / BLOCK_WORDS as f64;
        assert!((pool.fragmentation() - expect).abs() < 1e-12);
        pool.release_region(region, 1);
        // Peak (and its fragmentation snapshot) survive the release.
        assert_eq!(pool.peak_blocks(), 1);
        assert!((pool.fragmentation() - expect).abs() < 1e-12);
        // A full-block region raises the peak and clears the waste.
        let full = pool.acquire_region(2 * BLOCK_WORDS);
        assert_eq!(pool.peak_blocks(), 2);
        assert_eq!(pool.fragmentation(), 0.0);
        pool.release_region(full, 2 * BLOCK_WORDS);
    }

    #[test]
    fn block_shelf_cap_is_tunable_and_drops_keep_counting() {
        let pool = BlockPool::new();
        assert_eq!(pool.shelf_cap(), DEFAULT_BLOCK_SHELF_CAP);
        pool.set_shelf_cap(2);
        let region = pool.acquire_region(4 * BLOCK_WORDS);
        pool.release_region(region, 4 * BLOCK_WORDS);
        assert_eq!(pool.idle_blocks(), 2, "the tuned cap bounds the freelist");
        assert_eq!(pool.dropped(), 2, "blocks past the cap are dropped and counted");
        // Raising the cap takes effect on the next release.
        pool.set_shelf_cap(DEFAULT_BLOCK_SHELF_CAP);
        let region = pool.acquire_region(4 * BLOCK_WORDS);
        pool.release_region(region, 4 * BLOCK_WORDS);
        assert_eq!(pool.idle_blocks(), 4);
        assert_eq!(pool.dropped(), 2);
    }

    #[test]
    fn empty_pool_reports_zero_fragmentation() {
        let pool = BlockPool::new();
        assert_eq!(pool.fragmentation(), 0.0);
        assert_eq!(pool.peak_blocks(), 0);
        assert!(pool.acquire_region(0).is_empty());
        assert_eq!(pool.blocks_in_use(), 0);
    }

    #[test]
    fn paged_arena_roundtrips_and_releases_on_drop() {
        let pool = Arc::new(ArenaPool::new());
        let words = BLOCK_WORDS + 7;
        {
            let mut arena = PagedArena::new(Arc::clone(&pool), 3);
            assert!(!arena.is_mapped(1));
            arena.map(1, words);
            assert!(arena.is_mapped(1));
            assert_eq!(arena.words_of(1), words);
            let src: Vec<f32> = (0..words).map(|i| i as f32).collect();
            arena.scatter(1, &src);
            let mut dst = vec![0f32; words];
            arena.gather(1, &mut dst);
            assert_eq!(src, dst);
            arena.unmap(1);
            assert!(!arena.is_mapped(1));
            assert_eq!(pool.blocks().blocks_in_use(), 0);
            arena.map(2, 5);
            // Dropped while record 2 is still mapped.
        }
        assert_eq!(pool.blocks().blocks_in_use(), 0, "drop must release all blocks");
        assert!(pool.blocks().idle_blocks() >= 1);
    }
}
