//! The tensor arena: one pre-allocated block of memory materializing an
//! [`OffsetPlan`], plus the [`ArenaPool`] that recycles those blocks.
//!
//! §5: "a large chunk of memory is pre-allocated and the intermediate
//! tensors are given parts of the memory by the offsets within the memory
//! block." The arena is allocated once per executor (or per in-flight
//! request in the serving coordinator) — the whole point of the paper is
//! that this block is 7–10× smaller than the sum of tensor sizes. The pool
//! extends "allocated once" across executors and batch-size swaps: a
//! retired arena's buffer goes back on a size-classed freelist instead of
//! to the allocator.
//!
//! **Lanes**: an arena built for batch-scaled records (every size
//! multiplied by the batch, see `UsageRecords::scaled`) is striped into
//! `batch` equal lanes per tensor; sample *i* of a batch reads and writes
//! lane *i*, so a whole batch lives in one resident arena planned once.
//!
//! Debug builds add guard words between the arena and its end and a
//! poisoning facility used by the behavioural tests in `crate::exec` to
//! prove that planner bugs (overlapping live tensors) corrupt data and are
//! caught.

pub mod paged;
pub mod spill;

use crate::planner::OffsetPlan;
use crate::records::UsageRecords;
use paged::BlockPool;
use spill::SpillTier;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Value written over a tensor's region when it dies (debug feature): reads
/// of stale data then produce NaNs that propagate to the output checksum.
pub const POISON_F32: f32 = f32::NAN;

/// Guard word appended after the arena in debug builds.
const GUARD: f32 = 1.0e30;
const GUARD_WORDS: usize = 16;

/// Most buffers kept per size class; beyond this, released buffers are
/// dropped (bounds pool memory under engine churn).
const POOL_SHELF_CAP: usize = 8;

/// Size-classed freelist of arena buffers. Buffers are allocated at their
/// exact requested length (no power-of-two rounding — a pooled arena costs
/// the same memory as a fresh one) and shelved by the power-of-two class of
/// that length; `acquire` best-fits within the request's class and the one
/// above it. Shared across executors through `Arc`, with counters that
/// make reuse visible in serving metrics.
#[derive(Default)]
pub struct ArenaPool {
    /// `shelves[class]` holds buffers with `2^class <= len < 2^(class+1)`.
    shelves: Mutex<Vec<Vec<Vec<f32>>>>,
    reused: AtomicU64,
    allocated: AtomicU64,
    dropped: AtomicU64,
    /// Fixed-size block pool for paged decode-tail storage
    /// ([`paged::PagedArena`]); sharing the `ArenaPool` handle shares the
    /// blocks.
    blocks: BlockPool,
    /// Spill tier plus the residency watermark, once configured
    /// ([`Self::configure_spill`]). `None` — the default — keeps today's
    /// hold-everything-hot shelf behavior bit-for-bit.
    spill: Mutex<Option<SpillConfig>>,
}

/// The pool's view of its spill tier: where evicted buffers go and how
/// many idle resident bytes trigger eviction.
struct SpillConfig {
    tier: Arc<SpillTier>,
    /// Idle shelf bytes above which cold buffers are evicted into the
    /// tier, largest size class first, oldest buffer first.
    watermark_bytes: usize,
}

impl ArenaPool {
    /// Empty pool.
    pub fn new() -> Self {
        Self::default()
    }

    /// Size class of a word count: floor of log2.
    fn class_of(words: usize) -> usize {
        (usize::BITS - 1 - words.max(1).leading_zeros()) as usize
    }

    /// A buffer with `len >= words` whose first `words` elements are zero,
    /// recycled if a fitting one is shelved. Probes the request's own
    /// class (where an identically-sized buffer — the batch-swap and
    /// replica-restart case — always fits) and the class above (where
    /// every buffer fits); allocates exactly `words` on miss, so a pooled
    /// arena costs no more memory than a fresh one.
    pub fn acquire(&self, words: usize) -> Vec<f32> {
        let class = Self::class_of(words.max(1));
        {
            let mut shelves = self.shelves.lock().unwrap();
            for c in [class, class + 1] {
                if let Some(shelf) = shelves.get_mut(c) {
                    // Best fit, not first fit: take the *smallest* shelved
                    // buffer that covers the request, so a small request
                    // never strands the shelf's largest buffer.
                    let fit = shelf
                        .iter()
                        .enumerate()
                        .filter(|(_, b)| b.len() >= words)
                        .min_by_key(|&(_, b)| b.len())
                        .map(|(i, _)| i);
                    if let Some(i) = fit {
                        self.reused.fetch_add(1, Ordering::Relaxed);
                        let mut buf = shelf.swap_remove(i);
                        drop(shelves);
                        // Clear the previous arena's data; the tail past
                        // `words` is the caller's guard region.
                        buf[..words].fill(0.0);
                        return buf;
                    }
                }
            }
        }
        // Resident miss: before paying a fresh allocation, ask the spill
        // tier for an evicted buffer covering the request. The reload is
        // counted by the tier (not as a shelf reuse), so spill traffic
        // stays distinguishable in the metrics line.
        if let Some(tier) = self.spill_tier() {
            if let Some(mut buf) = tier.reload(words) {
                buf[..words].fill(0.0);
                return buf;
            }
        }
        self.allocated.fetch_add(1, Ordering::Relaxed);
        vec![0f32; words]
    }

    /// Shelve a buffer for reuse; buffers of any length are accepted.
    /// Buffers past the per-class retention cap are dropped and counted
    /// ([`Self::dropped`]) so pool churn is visible in serving metrics.
    pub fn release(&self, buf: Vec<f32>) {
        if buf.is_empty() {
            return;
        }
        let class = Self::class_of(buf.len());
        let mut shelves = self.shelves.lock().unwrap();
        if shelves.len() <= class {
            shelves.resize_with(class + 1, Vec::new);
        }
        let shelf = &mut shelves[class];
        if shelf.len() < POOL_SHELF_CAP {
            shelf.push(buf);
        } else {
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        drop(shelves);
        self.enforce_spill_watermark();
    }

    /// Attach a spill tier: idle shelf bytes above `watermark_bytes` are
    /// evicted (compressed) into `tier` instead of staying hot, and
    /// [`Self::acquire`] misses demand-reload from it before allocating
    /// fresh. The watermark is enforced immediately over whatever is
    /// already shelved.
    pub fn configure_spill(&self, tier: Arc<SpillTier>, watermark_bytes: usize) {
        *self.spill.lock().unwrap() = Some(SpillConfig { tier, watermark_bytes });
        self.enforce_spill_watermark();
    }

    /// The attached spill tier, if any.
    pub fn spill_tier(&self) -> Option<Arc<SpillTier>> {
        self.spill.lock().unwrap().as_ref().map(|c| Arc::clone(&c.tier))
    }

    /// The configured residency watermark in bytes, if a tier is attached.
    pub fn spill_watermark_bytes(&self) -> Option<usize> {
        self.spill.lock().unwrap().as_ref().map(|c| c.watermark_bytes)
    }

    /// Evict cold idle shelf buffers into the spill tier until resident
    /// idle bytes are back under the watermark: largest size class first
    /// (the residency that costs the most), oldest buffer within the class
    /// first (the coldest). A no-op with no tier configured.
    fn enforce_spill_watermark(&self) {
        let (tier, watermark) = {
            let cfg = self.spill.lock().unwrap();
            match cfg.as_ref() {
                Some(c) => (Arc::clone(&c.tier), c.watermark_bytes),
                None => return,
            }
        };
        let mut evicted = Vec::new();
        {
            let mut shelves = self.shelves.lock().unwrap();
            let mut idle: usize = shelves.iter().flatten().map(|b| b.len() * 4).sum();
            while idle > watermark {
                let Some(shelf) = shelves.iter_mut().rev().find(|s| !s.is_empty()) else {
                    break;
                };
                let buf = shelf.remove(0);
                idle -= buf.len() * 4;
                evicted.push(buf);
            }
        }
        // Compress outside the shelf lock so eviction never stalls a
        // concurrent acquire.
        for buf in evicted {
            tier.spill(buf);
        }
    }

    /// Buffers recycled so far.
    pub fn reused(&self) -> u64 {
        self.reused.load(Ordering::Relaxed)
    }

    /// Buffers freshly allocated so far.
    pub fn allocated(&self) -> u64 {
        self.allocated.load(Ordering::Relaxed)
    }

    /// Buffers dropped at release because their size class was at the
    /// retention cap.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// The shared fixed-size block pool backing paged decode-tail arenas
    /// ([`paged::PagedArena`]). Every executor holding a clone of this
    /// pool's `Arc` draws tail blocks from the same freelist.
    pub fn blocks(&self) -> &BlockPool {
        &self.blocks
    }

    /// Buffers currently shelved (for tests and pool introspection).
    pub fn idle_buffers(&self) -> usize {
        self.shelves.lock().unwrap().iter().map(Vec::len).sum()
    }
}

/// A planned arena of `f32` words (all tensor offsets/sizes in this crate
/// are 64-byte aligned, so `f32` indexing is always exact).
pub struct Arena {
    buf: Vec<f32>,
    /// Byte offsets per record id, from the plan.
    offsets: Vec<usize>,
    /// Byte sizes per record id, from the records (batch-scaled when
    /// `lanes > 1`).
    sizes: Vec<usize>,
    /// Batch lanes each record's region is striped into.
    lanes: usize,
    /// First guard word; everything from here to `buf.len()` is guard.
    guard_from: usize,
}

impl Arena {
    /// Allocate a fresh (unpooled) arena for `plan` over `records`. Panics
    /// if the plan does not cover the records (use `plan.validate` first
    /// for a nice error).
    pub fn new(plan: &OffsetPlan, records: &UsageRecords) -> Self {
        let words = plan.total / 4 + GUARD_WORDS;
        Self::build(plan, records, 1, vec![0f32; words])
    }

    /// Arena from a pooled buffer, striped into `lanes` batch lanes.
    /// `records` must be the lane-scaled records matching `plan` (every
    /// size divisible by `4 * lanes`). Return the buffer with
    /// [`Arena::recycle`] when the arena is retired.
    pub fn from_pool(
        plan: &OffsetPlan,
        records: &UsageRecords,
        lanes: usize,
        pool: &ArenaPool,
    ) -> Self {
        let words = plan.total / 4 + GUARD_WORDS;
        let buf = pool.acquire(words);
        debug_assert!(buf.len() >= words);
        Self::build(plan, records, lanes, buf)
    }

    fn build(plan: &OffsetPlan, records: &UsageRecords, lanes: usize, mut buf: Vec<f32>) -> Self {
        assert_eq!(plan.offsets.len(), records.len());
        assert!(lanes >= 1, "an arena needs at least one lane");
        for r in &records.records {
            // Hard bound: the lane/range arithmetic below feeds unchecked
            // raw-pointer slices in `split_io_lane`, so every record must
            // provably fit inside the arena.
            assert!(
                plan.offsets[r.id] + r.size <= plan.total,
                "record {} at {}..{} exceeds arena total {}",
                r.id,
                plan.offsets[r.id],
                plan.offsets[r.id] + r.size,
                plan.total
            );
            debug_assert!(
                r.size % (4 * lanes) == 0,
                "record {} size {} not striping into {lanes} lanes",
                r.id,
                r.size
            );
        }
        let guard_from = plan.total / 4;
        for g in &mut buf[guard_from..] {
            *g = GUARD;
        }
        Arena {
            buf,
            offsets: plan.offsets.clone(),
            sizes: records.records.iter().map(|r| r.size).collect(),
            lanes,
            guard_from,
        }
    }

    /// A zero-capacity placeholder (used while swapping arenas).
    pub fn empty() -> Self {
        Arena {
            buf: Vec::new(),
            offsets: Vec::new(),
            sizes: Vec::new(),
            lanes: 1,
            guard_from: 0,
        }
    }

    /// Retire the arena, shelving its buffer for the next one.
    pub fn recycle(self, pool: &ArenaPool) {
        pool.release(self.buf);
    }

    /// Arena capacity in bytes (excluding guards).
    pub fn capacity(&self) -> usize {
        self.guard_from * 4
    }

    /// Number of batch lanes.
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// Word range of a record's whole (all-lane) region.
    fn range(&self, record: usize) -> std::ops::Range<usize> {
        let start = self.offsets[record] / 4;
        start..start + self.sizes[record] / 4
    }

    /// Word range of one lane's stripe of a record. The lane bound is a
    /// hard assert: these ranges feed the raw-pointer slices of
    /// [`Self::split_io_lane`], so an out-of-range lane must never produce
    /// a range past the record's region.
    fn lane_range(&self, record: usize, lane: usize) -> std::ops::Range<usize> {
        assert!(lane < self.lanes, "lane {lane} of a {}-lane arena", self.lanes);
        let stripe = self.sizes[record] / self.lanes / 4;
        let start = self.offsets[record] / 4 + lane * stripe;
        start..start + stripe
    }

    /// Read-only view of a tensor's whole region (all lanes).
    pub fn tensor(&self, record: usize) -> &[f32] {
        &self.buf[self.range(record)]
    }

    /// Mutable view of a tensor's whole region (all lanes).
    pub fn tensor_mut(&mut self, record: usize) -> &mut [f32] {
        let r = self.range(record);
        &mut self.buf[r]
    }

    /// Read-only view of one lane's stripe of a tensor.
    pub fn tensor_lane(&self, record: usize, lane: usize) -> &[f32] {
        &self.buf[self.lane_range(record, lane)]
    }

    /// Simultaneous access to one output tensor and several input tensors
    /// (lane 0 — the single-sample path).
    pub fn split_io(&mut self, output: usize, inputs: &[usize]) -> (&mut [f32], Vec<&[f32]>) {
        self.split_io_lane(output, inputs, 0)
    }

    /// Simultaneous access to one output stripe and several input stripes
    /// of batch lane `lane`.
    ///
    /// Safety argument: in any *valid* plan the output and all inputs of an
    /// op are simultaneously live (their usage intervals all contain the
    /// op), therefore their byte ranges — and a fortiori their same-lane
    /// stripes — are pairwise disjoint; the runtime check below enforces it
    /// even for hand-built plans.
    pub fn split_io_lane(
        &mut self,
        output: usize,
        inputs: &[usize],
        lane: usize,
    ) -> (&mut [f32], Vec<&[f32]>) {
        let out_range = self.lane_range(output, lane);
        for &i in inputs {
            let r = self.lane_range(i, lane);
            assert!(
                r.end <= out_range.start || out_range.end <= r.start,
                "op I/O overlap in arena: record {i} ({r:?}) vs output {output} ({out_range:?}) — invalid plan"
            );
        }
        let base = self.buf.as_mut_ptr();
        // SAFETY: ranges are in-bounds (checked by `lane_range`) and the
        // output range is disjoint from every input range (asserted above);
        // inputs may alias each other but are only handed out as shared
        // slices.
        unsafe {
            let out = std::slice::from_raw_parts_mut(
                base.add(out_range.start),
                out_range.end - out_range.start,
            );
            let ins = inputs
                .iter()
                .map(|&i| {
                    let r = self.lane_range(i, lane);
                    std::slice::from_raw_parts(base.add(r.start) as *const f32, r.end - r.start)
                })
                .collect();
            (out, ins)
        }
    }

    /// Poison a dead tensor's whole region (debug/behavioural-test aid).
    pub fn poison(&mut self, record: usize) {
        for v in self.tensor_mut(record) {
            *v = POISON_F32;
        }
    }

    /// Poison one lane's stripe of a dead tensor.
    pub fn poison_lane(&mut self, record: usize, lane: usize) {
        let r = self.lane_range(record, lane);
        for v in &mut self.buf[r] {
            *v = POISON_F32;
        }
    }

    /// Check the end-of-arena guard words; true if untouched.
    pub fn guards_intact(&self) -> bool {
        self.buf[self.guard_from..].iter().all(|&g| g == GUARD)
    }

    /// Byte range `[start, end)` of a record's whole (all-lane) region —
    /// the offset-range half of the parallel executor's non-aliasing proof
    /// (the other half is the planner's lifetime intervals).
    pub fn record_span(&self, record: usize) -> (usize, usize) {
        (self.offsets[record], self.offsets[record] + self.sizes[record])
    }

    /// A `Send + Sync` view of this arena for the parallel executor: worker
    /// threads carve per-record, per-lane slices out of one shared buffer.
    ///
    /// The `&mut self` receiver makes the borrow checker prove the view has
    /// *exclusive* access to the buffer for its whole lifetime (no safe
    /// `&Arena`/`&mut Arena` method can race with it); splitting that
    /// exclusive access into concurrently-used disjoint slices is the
    /// caller's obligation, which is why every accessor on the view is
    /// `unsafe` — see [`ParallelArena::split_io_lane`] for the contract the
    /// executor's level schedule discharges.
    pub fn parallel_view(&mut self) -> ParallelArena<'_> {
        ParallelArena {
            base: self.buf.as_mut_ptr(),
            words: self.guard_from,
            offsets: self.offsets.clone(),
            sizes: self.sizes.clone(),
            lanes: self.lanes,
            _lock: std::marker::PhantomData,
        }
    }
}

/// Shared-buffer view used by the parallel executor (see
/// [`Arena::parallel_view`]). Holds a raw base pointer plus a copy of the
/// record layout; the phantom `&mut Arena` keeps the source arena
/// exclusively borrowed for the view's lifetime.
pub struct ParallelArena<'a> {
    base: *mut f32,
    /// Words before the guard region; every range below must end here.
    words: usize,
    offsets: Vec<usize>,
    sizes: Vec<usize>,
    lanes: usize,
    _lock: std::marker::PhantomData<&'a mut Arena>,
}

// SAFETY: the view is only a (pointer, layout) pair. All dereferences go
// through the `unsafe` accessors below, whose contracts require the caller
// to hand disjoint ranges to concurrent threads; the borrow on the source
// `Arena` prevents any non-view access for the view's lifetime.
unsafe impl Send for ParallelArena<'_> {}
unsafe impl Sync for ParallelArena<'_> {}

impl ParallelArena<'_> {
    /// Word range of one lane's stripe of a record (same arithmetic as
    /// [`Arena::lane_range`], with the same hard bounds).
    fn lane_range(&self, record: usize, lane: usize) -> std::ops::Range<usize> {
        assert!(lane < self.lanes, "lane {lane} of a {}-lane arena", self.lanes);
        let stripe = self.sizes[record] / self.lanes / 4;
        let start = self.offsets[record] / 4 + lane * stripe;
        let range = start..start + stripe;
        assert!(range.end <= self.words, "record {record} exceeds the arena");
        range
    }

    /// Read-only view of one lane's stripe of a record.
    ///
    /// # Safety
    ///
    /// No concurrently-running thread may hold a mutable slice overlapping
    /// this stripe. The executor guarantees it two ways: in lockstep batch
    /// mode all threads execute the same op (whose tensors are mutually
    /// live, hence byte-disjoint by plan validation); in level mode the
    /// schedule only groups ops whose offset ranges were proven disjoint.
    pub unsafe fn tensor_lane(&self, record: usize, lane: usize) -> &[f32] {
        let r = self.lane_range(record, lane);
        std::slice::from_raw_parts(self.base.add(r.start) as *const f32, r.end - r.start)
    }

    /// Simultaneous access to one output stripe and several input stripes
    /// of batch lane `lane` — the parallel twin of
    /// [`Arena::split_io_lane`], including its output-vs-input overlap
    /// assert.
    ///
    /// # Safety
    ///
    /// Same contract as [`Self::tensor_lane`], plus: no concurrent thread
    /// may hold *any* slice overlapping the output stripe. The executor's
    /// schedule (lockstep same-op, or level groups with pairwise-disjoint
    /// offset ranges) discharges this.
    pub unsafe fn split_io_lane(
        &self,
        output: usize,
        inputs: &[usize],
        lane: usize,
    ) -> (&mut [f32], Vec<&[f32]>) {
        let out_range = self.lane_range(output, lane);
        for &i in inputs {
            let r = self.lane_range(i, lane);
            assert!(
                r.end <= out_range.start || out_range.end <= r.start,
                "op I/O overlap in arena: record {i} ({r:?}) vs output {output} ({out_range:?}) — invalid plan"
            );
        }
        let out = std::slice::from_raw_parts_mut(
            self.base.add(out_range.start),
            out_range.end - out_range.start,
        );
        let ins = inputs
            .iter()
            .map(|&i| {
                let r = self.lane_range(i, lane);
                std::slice::from_raw_parts(self.base.add(r.start) as *const f32, r.end - r.start)
            })
            .collect();
        (out, ins)
    }

    /// Poison one lane's stripe of a dead record (the parallel twin of
    /// [`Arena::poison_lane`]).
    ///
    /// # Safety
    ///
    /// Same exclusivity contract as [`Self::split_io_lane`]'s output: no
    /// concurrent thread may hold any slice overlapping the stripe. A
    /// record is only poisoned at its last use, where it is still live, so
    /// plan validation keeps its range disjoint from every other tensor
    /// touched at that op.
    pub unsafe fn poison_lane(&self, record: usize, lane: usize) {
        let r = self.lane_range(record, lane);
        let s = std::slice::from_raw_parts_mut(self.base.add(r.start), r.end - r.start);
        for v in s {
            *v = POISON_F32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{offset::GreedyBySize, OffsetPlanner};

    fn setup() -> (UsageRecords, OffsetPlan) {
        // Sizes are multiples of 64 bytes.
        let recs = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128), (2, 3, 64)]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        (recs, plan)
    }

    #[test]
    fn read_write_roundtrip() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        assert!(arena.capacity() >= plan.total);
        arena.tensor_mut(0).fill(3.5);
        assert!(arena.tensor(0).iter().all(|&v| v == 3.5));
        assert_eq!(arena.tensor(0).len(), 16); // 64 bytes
        assert_eq!(arena.tensor(1).len(), 32);
    }

    #[test]
    fn split_io_gives_disjoint_views() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        arena.tensor_mut(0).fill(2.0);
        let (out, ins) = arena.split_io(1, &[0]);
        assert_eq!(ins[0].len(), 16);
        assert!(ins[0].iter().all(|&v| v == 2.0));
        out.fill(4.0);
        assert!(arena.tensor(1).iter().all(|&v| v == 4.0));
    }

    #[test]
    #[should_panic(expected = "op I/O overlap")]
    fn split_io_rejects_overlapping_plan() {
        let recs = UsageRecords::from_triples(&[(0, 1, 64), (0, 1, 64)]);
        // Deliberately broken plan: both records at offset 0.
        let plan = OffsetPlan { offsets: vec![0, 0], total: 64 };
        let mut arena = Arena::new(&plan, &recs);
        let _ = arena.split_io(1, &[0]);
    }

    #[test]
    fn guards_and_poison() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        assert!(arena.guards_intact());
        arena.poison(2);
        assert!(arena.tensor(2).iter().all(|v| v.is_nan()));
        assert!(arena.guards_intact());
    }

    #[test]
    fn lanes_stripe_each_record_disjointly() {
        let base = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        let scaled = base.scaled(4);
        let plan = GreedyBySize.plan(&scaled);
        plan.validate(&scaled).unwrap();
        let pool = ArenaPool::new();
        let mut arena = Arena::from_pool(&plan, &scaled, 4, &pool);
        assert_eq!(arena.lanes(), 4);
        assert_eq!(arena.tensor_lane(0, 0).len(), 16); // one 64-byte stripe
        assert_eq!(arena.tensor(0).len(), 64); // 4 lanes
        // Write each lane a distinct value; no lane may clobber another.
        for lane in 0..4 {
            let (out, _) = arena.split_io_lane(0, &[], lane);
            out.fill(lane as f32 + 1.0);
        }
        for lane in 0..4 {
            assert!(
                arena.tensor_lane(0, lane).iter().all(|&v| v == lane as f32 + 1.0),
                "lane {lane} clobbered"
            );
        }
        assert!(arena.guards_intact());
        // Lane poison touches one stripe only.
        arena.poison_lane(0, 2);
        assert!(arena.tensor_lane(0, 2).iter().all(|v| v.is_nan()));
        assert!(arena.tensor_lane(0, 1).iter().all(|&v| v == 2.0));
    }

    #[test]
    fn pool_recycles_buffers_and_counts() {
        let (recs, plan) = setup();
        let pool = ArenaPool::new();
        let a = Arena::from_pool(&plan, &recs, 1, &pool);
        assert_eq!((pool.allocated(), pool.reused()), (1, 0));
        a.recycle(&pool);
        assert_eq!(pool.idle_buffers(), 1);
        // Same size class: the buffer comes back.
        let b = Arena::from_pool(&plan, &recs, 1, &pool);
        assert_eq!((pool.allocated(), pool.reused()), (1, 1));
        assert_eq!(pool.idle_buffers(), 0);
        // A fresh pooled arena must not see the old arena's data.
        assert!(b.tensor(0).iter().all(|&v| v == 0.0));
        assert!(b.guards_intact());
        b.recycle(&pool);
    }

    #[test]
    fn pool_acquire_covers_requested_words() {
        let pool = ArenaPool::new();
        for words in [1usize, 2, 3, 16, 17, 1000] {
            let buf = pool.acquire(words);
            assert!(buf.len() >= words, "{words} words got {}", buf.len());
            pool.release(buf);
        }
        // Shelf cap bounds retained buffers.
        for _ in 0..20 {
            pool.release(vec![0f32; 64]);
        }
        assert!(pool.idle_buffers() <= 20);
    }

    #[test]
    fn pool_acquire_is_best_fit_within_a_class() {
        // Regression: first-fit used to hand out whichever fitting buffer
        // was shelved first, stranding the class's largest buffer on a
        // small request. 2000 and 1700 words share class 10; shelving the
        // larger first makes first-fit pick it for a 1600-word request.
        let pool = ArenaPool::new();
        pool.release(vec![0f32; 2000]);
        pool.release(vec![0f32; 1700]);
        let got = pool.acquire(1600);
        assert_eq!(got.len(), 1700, "best fit must pick the smallest fitting buffer");
        assert_eq!(pool.idle_buffers(), 1, "the 2000-word buffer stays shelved");
        // The remaining large buffer still serves the next large request.
        let big = pool.acquire(1900);
        assert_eq!(big.len(), 2000);
        assert_eq!((pool.allocated(), pool.reused()), (0, 2));
        pool.release(got);
        pool.release(big);
    }

    #[test]
    fn pool_release_counts_dropped_buffers_past_the_cap() {
        let pool = ArenaPool::new();
        for _ in 0..POOL_SHELF_CAP + 3 {
            pool.release(vec![0f32; 64]);
        }
        assert_eq!(pool.idle_buffers(), POOL_SHELF_CAP);
        assert_eq!(pool.dropped(), 3);
        // Empty buffers are ignored, not dropped.
        pool.release(Vec::new());
        assert_eq!(pool.dropped(), 3);
    }

    #[test]
    fn pool_evicts_past_the_watermark_and_reloads_on_demand() {
        let pool = ArenaPool::new();
        let tier = Arc::new(SpillTier::new());
        // 4 KiB watermark: two 1000-word (4000-byte) buffers exceed it.
        pool.configure_spill(Arc::clone(&tier), 4096);
        pool.release(vec![0f32; 1000]);
        assert_eq!((pool.idle_buffers(), tier.entries()), (1, 0));
        pool.release(vec![0f32; 1000]);
        // 8000 idle bytes > 4096: the oldest buffer spills.
        assert_eq!((pool.idle_buffers(), tier.entries()), (1, 1));
        assert_eq!(tier.evictions(), 1);
        // First acquire drains the shelf, second demand-reloads the
        // spilled buffer instead of allocating fresh.
        let a = pool.acquire(1000);
        let b = pool.acquire(1000);
        assert_eq!((a.len(), b.len()), (1000, 1000));
        assert!(b.iter().all(|&v| v == 0.0), "reloaded buffers are zeroed");
        assert_eq!(tier.reloads(), 1);
        assert_eq!(pool.allocated(), 0, "the reload must beat a fresh allocation");
        // A third acquire misses both tiers and allocates.
        let c = pool.acquire(1000);
        assert_eq!(pool.allocated(), 1);
        drop((a, b, c));
    }

    #[test]
    fn pool_configure_spill_evicts_existing_idle_buffers() {
        let pool = ArenaPool::new();
        pool.release(vec![1.5f32; 2048]);
        pool.release(vec![2.5f32; 512]);
        let tier = Arc::new(SpillTier::new());
        // Watermark 0: everything idle evicts the moment the tier attaches,
        // largest class first.
        pool.configure_spill(Arc::clone(&tier), 0);
        assert_eq!(pool.idle_buffers(), 0);
        assert_eq!(tier.entries(), 2);
        // Reloads are bit-exact through the codec.
        let big = pool.acquire(2048);
        assert_eq!(big.len(), 2048);
        assert_eq!(tier.reloads(), 1);
        // An unconfigured pool keeps today's behavior.
        let plain = ArenaPool::new();
        assert!(plain.spill_tier().is_none());
        assert!(plain.spill_watermark_bytes().is_none());
    }

    #[test]
    fn parallel_view_matches_arena_layout_and_is_send() {
        fn assert_sync<T: Send + Sync>(_: &T) {}
        let base = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128)]);
        let scaled = base.scaled(2);
        let plan = GreedyBySize.plan(&scaled);
        plan.validate(&scaled).unwrap();
        let pool = ArenaPool::new();
        let mut arena = Arena::from_pool(&plan, &scaled, 2, &pool);
        let spans: Vec<_> = (0..2).map(|r| arena.record_span(r)).collect();
        assert!(spans.iter().all(|&(s, e)| e > s && e <= plan.total));
        {
            let view = arena.parallel_view();
            assert_sync(&view);
            // Writes through the view land exactly where Arena would put
            // them, lane by lane.
            std::thread::scope(|s| {
                for lane in 0..2 {
                    let view = &view;
                    s.spawn(move || {
                        // SAFETY: each thread touches its own lane of
                        // record 0 only; stripes of one record are
                        // disjoint across lanes.
                        let (out, _) = unsafe { view.split_io_lane(0, &[], lane) };
                        out.fill(lane as f32 + 1.0);
                    });
                }
            });
        }
        for lane in 0..2 {
            assert!(
                arena.tensor_lane(0, lane).iter().all(|&v| v == lane as f32 + 1.0),
                "lane {lane} clobbered through the view"
            );
        }
        assert!(arena.guards_intact());
    }

    #[test]
    #[should_panic(expected = "op I/O overlap")]
    fn parallel_view_rejects_overlapping_plan() {
        let recs = UsageRecords::from_triples(&[(0, 1, 64), (0, 1, 64)]);
        let plan = OffsetPlan { offsets: vec![0, 0], total: 64 };
        let mut arena = Arena::new(&plan, &recs);
        let view = arena.parallel_view();
        // SAFETY: single-threaded; the overlap assert fires before any
        // slice is handed out.
        let _ = unsafe { view.split_io_lane(1, &[0], 0) };
    }

    #[test]
    fn empty_arena_is_inert() {
        let arena = Arena::empty();
        assert_eq!(arena.capacity(), 0);
        assert!(arena.guards_intact());
        let pool = ArenaPool::new();
        arena.recycle(&pool); // empty buffers are not shelved
        assert_eq!(pool.idle_buffers(), 0);
    }
}
