//! The tensor arena: one pre-allocated block of memory materializing an
//! [`OffsetPlan`].
//!
//! §5: "a large chunk of memory is pre-allocated and the intermediate
//! tensors are given parts of the memory by the offsets within the memory
//! block." The arena is allocated once per executor (or per in-flight
//! request in the serving coordinator) — the whole point of the paper is
//! that this block is 7–10× smaller than the sum of tensor sizes.
//!
//! Debug builds add guard words between the arena and its end and a
//! poisoning facility used by the behavioural tests in `crate::exec` to
//! prove that planner bugs (overlapping live tensors) corrupt data and are
//! caught.

use crate::planner::OffsetPlan;
use crate::records::UsageRecords;

/// Value written over a tensor's region when it dies (debug feature): reads
/// of stale data then produce NaNs that propagate to the output checksum.
pub const POISON_F32: f32 = f32::NAN;

/// Guard word appended after the arena in debug builds.
const GUARD: f32 = 1.0e30;
const GUARD_WORDS: usize = 16;

/// A planned arena of `f32` words (all tensor offsets/sizes in this crate
/// are 64-byte aligned, so `f32` indexing is always exact).
pub struct Arena {
    buf: Vec<f32>,
    /// Byte offsets per record id, from the plan.
    offsets: Vec<usize>,
    /// Byte sizes per record id, from the records.
    sizes: Vec<usize>,
}

impl Arena {
    /// Allocate an arena for `plan` over `records`. Panics if the plan does
    /// not cover the records (use `plan.validate` first for a nice error).
    pub fn new(plan: &OffsetPlan, records: &UsageRecords) -> Self {
        assert_eq!(plan.offsets.len(), records.len());
        let words = plan.total / 4 + GUARD_WORDS;
        let mut buf = vec![0f32; words];
        for g in &mut buf[plan.total / 4..] {
            *g = GUARD;
        }
        Arena {
            buf,
            offsets: plan.offsets.clone(),
            sizes: records.records.iter().map(|r| r.size).collect(),
        }
    }

    /// Arena capacity in bytes (excluding guards).
    pub fn capacity(&self) -> usize {
        (self.buf.len() - GUARD_WORDS) * 4
    }

    /// Word range of a record.
    fn range(&self, record: usize) -> std::ops::Range<usize> {
        let start = self.offsets[record] / 4;
        start..start + self.sizes[record] / 4
    }

    /// Read-only view of a tensor's buffer.
    pub fn tensor(&self, record: usize) -> &[f32] {
        &self.buf[self.range(record)]
    }

    /// Mutable view of a tensor's buffer.
    pub fn tensor_mut(&mut self, record: usize) -> &mut [f32] {
        let r = self.range(record);
        &mut self.buf[r]
    }

    /// Simultaneous access to one output tensor and several input tensors.
    ///
    /// Safety argument: in any *valid* plan the output and all inputs of an
    /// op are simultaneously live (their usage intervals all contain the
    /// op), therefore their byte ranges are pairwise disjoint; the runtime
    /// check below enforces it even for hand-built plans.
    pub fn split_io(&mut self, output: usize, inputs: &[usize]) -> (&mut [f32], Vec<&[f32]>) {
        let out_range = self.range(output);
        for &i in inputs {
            let r = self.range(i);
            assert!(
                r.end <= out_range.start || out_range.end <= r.start,
                "op I/O overlap in arena: record {i} ({r:?}) vs output {output} ({out_range:?}) — invalid plan"
            );
        }
        let base = self.buf.as_mut_ptr();
        // SAFETY: ranges are in-bounds (checked by `range`) and the output
        // range is disjoint from every input range (asserted above); inputs
        // may alias each other but are only handed out as shared slices.
        unsafe {
            let out = std::slice::from_raw_parts_mut(
                base.add(out_range.start),
                out_range.end - out_range.start,
            );
            let ins = inputs
                .iter()
                .map(|&i| {
                    let r = self.range(i);
                    std::slice::from_raw_parts(base.add(r.start) as *const f32, r.end - r.start)
                })
                .collect();
            (out, ins)
        }
    }

    /// Poison a dead tensor's region (debug/behavioural-test aid).
    pub fn poison(&mut self, record: usize) {
        for v in self.tensor_mut(record) {
            *v = POISON_F32;
        }
    }

    /// Check the end-of-arena guard words; true if untouched.
    pub fn guards_intact(&self) -> bool {
        self.buf[self.buf.len() - GUARD_WORDS..]
            .iter()
            .all(|&g| g == GUARD)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::{offset::GreedyBySize, OffsetPlanner};

    fn setup() -> (UsageRecords, OffsetPlan) {
        // Sizes are multiples of 64 bytes.
        let recs = UsageRecords::from_triples(&[(0, 1, 64), (1, 2, 128), (2, 3, 64)]);
        let plan = GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        (recs, plan)
    }

    #[test]
    fn read_write_roundtrip() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        assert!(arena.capacity() >= plan.total);
        arena.tensor_mut(0).fill(3.5);
        assert!(arena.tensor(0).iter().all(|&v| v == 3.5));
        assert_eq!(arena.tensor(0).len(), 16); // 64 bytes
        assert_eq!(arena.tensor(1).len(), 32);
    }

    #[test]
    fn split_io_gives_disjoint_views() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        arena.tensor_mut(0).fill(2.0);
        let (out, ins) = arena.split_io(1, &[0]);
        assert_eq!(ins[0].len(), 16);
        assert!(ins[0].iter().all(|&v| v == 2.0));
        out.fill(4.0);
        assert!(arena.tensor(1).iter().all(|&v| v == 4.0));
    }

    #[test]
    #[should_panic(expected = "op I/O overlap")]
    fn split_io_rejects_overlapping_plan() {
        let recs = UsageRecords::from_triples(&[(0, 1, 64), (0, 1, 64)]);
        // Deliberately broken plan: both records at offset 0.
        let plan = OffsetPlan { offsets: vec![0, 0], total: 64 };
        let mut arena = Arena::new(&plan, &recs);
        let _ = arena.split_io(1, &[0]);
    }

    #[test]
    fn guards_and_poison() {
        let (recs, plan) = setup();
        let mut arena = Arena::new(&plan, &recs);
        assert!(arena.guards_intact());
        arena.poison(2);
        assert!(arena.tensor(2).iter().all(|v| v.is_nan()));
        assert!(arena.guards_intact());
    }
}
