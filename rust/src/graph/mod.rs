//! Graph IR for DNN inference.
//!
//! A [`Graph`] is a directed acyclic graph of [`Op`]s connected by
//! [`Tensor`]s, mirroring the representation in §1 of the paper: nodes are
//! computational operators (CONVOLUTION, SOFTMAX, ...) and edges are the
//! tensors holding intermediate results. Operator execution order is the
//! fixed topological order in which ops were added (TFLite semantics — the
//! paper assumes the topological sort is fixed, §3).
//!
//! Tensors are classified by [`TensorKind`]: only `Intermediate` tensors
//! participate in memory planning; graph inputs/outputs and weights are
//! allocated separately (the paper's Figure 1 note: "tensor #8 is not an
//! intermediate tensor").

mod builder;
mod node;
mod shape;
mod topo;

pub use builder::GraphBuilder;
pub use node::{Activation, Op, OpId, OpKind, PoolKind};
pub use shape::{conv_out_dim, same_padding, same_padding_pair, Padding};
pub use topo::{is_valid_execution_order, topo_levels, topo_sort};

use crate::align;


/// Element type of a tensor. The paper evaluates at 32-bit float; `F16` and
/// `U8` are provided for quantized-model planning experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// 32-bit IEEE float (the paper's evaluation precision).
    F32,
    /// 16-bit float.
    F16,
    /// 8-bit unsigned (quantized models).
    U8,
    /// 32-bit signed integer.
    I32,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size_of(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::F16 => 2,
            DType::U8 => 1,
        }
    }
}

/// How a tensor is stored and whether it participates in planning.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TensorKind {
    /// Network input: externally provided, never planned.
    Input,
    /// Network output: externally retained, never planned (Figure 1's
    /// tensor #8).
    Output,
    /// Intermediate activation: the subject of this paper.
    Intermediate,
    /// Weight / constant: lives in the (read-only) model file, never planned.
    Weight,
}

/// Unique id of a tensor within its graph (dense, 0-based).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TensorId(pub usize);

/// A tensor: a named, shaped, typed edge of the graph.
#[derive(Debug, Clone)]
pub struct Tensor {
    /// Dense id inside the owning graph.
    pub id: TensorId,
    /// Human-readable name (layer name in the zoo models).
    pub name: String,
    /// Logical shape, typically `[N, H, W, C]` (NHWC, as TFLite uses).
    pub shape: Vec<usize>,
    /// Element type.
    pub dtype: DType,
    /// Storage class — only [`TensorKind::Intermediate`] is planned.
    pub kind: TensorKind,
}

impl Tensor {
    /// Number of elements.
    pub fn num_elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Unaligned byte size.
    pub fn byte_size(&self) -> usize {
        self.num_elements() * self.dtype.size_of()
    }

    /// Aligned byte size — the `size_t` of the paper's tensor usage record.
    pub fn aligned_size(&self) -> usize {
        align(self.byte_size())
    }
}

/// A DNN inference graph: ops in execution order plus the tensors they
/// exchange.
#[derive(Debug, Clone, Default)]
pub struct Graph {
    /// Model name (zoo key).
    pub name: String,
    /// Every tensor, indexed by [`TensorId`].
    pub tensors: Vec<Tensor>,
    /// Ops in execution (topological) order; `ops[i].id == OpId(i)`.
    pub ops: Vec<Op>,
    /// Graph input tensors, in declaration order.
    pub inputs: Vec<TensorId>,
    /// Graph output tensors, in declaration order.
    pub outputs: Vec<TensorId>,
}

impl Graph {
    /// Look up a tensor.
    pub fn tensor(&self, id: TensorId) -> &Tensor {
        &self.tensors[id.0]
    }

    /// Look up an op.
    pub fn op(&self, id: OpId) -> &Op {
        &self.ops[id.0]
    }

    /// All intermediate tensors (the planning universe).
    pub fn intermediates(&self) -> impl Iterator<Item = &Tensor> {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Intermediate)
    }

    /// Total aligned bytes of intermediate tensors — the paper's "Naive"
    /// baseline (every tensor gets its own buffer).
    pub fn naive_intermediate_bytes(&self) -> usize {
        self.intermediates().map(|t| t.aligned_size()).sum()
    }

    /// Total aligned bytes of weight tensors (context for §1's "37% of
    /// 147 MB" style statements).
    pub fn weight_bytes(&self) -> usize {
        self.tensors
            .iter()
            .filter(|t| t.kind == TensorKind::Weight)
            .map(|t| t.aligned_size())
            .sum()
    }

    /// Number of ops.
    pub fn num_ops(&self) -> usize {
        self.ops.len()
    }

    /// Validate structural invariants: ids dense and in range, every
    /// non-input tensor produced by exactly one op before any consumer,
    /// execution order topologically valid.
    pub fn validate(&self) -> Result<(), String> {
        for (i, t) in self.tensors.iter().enumerate() {
            if t.id.0 != i {
                return Err(format!("tensor {} has id {:?}", i, t.id));
            }
            if t.shape.is_empty() || t.num_elements() == 0 {
                return Err(format!("tensor {} ({}) has empty shape", i, t.name));
            }
        }
        for (i, op) in self.ops.iter().enumerate() {
            if op.id.0 != i {
                return Err(format!("op {} has id {:?}", i, op.id));
            }
            for &tid in op.inputs.iter().chain(op.outputs.iter()) {
                if tid.0 >= self.tensors.len() {
                    return Err(format!("op {} references missing tensor {:?}", op.name, tid));
                }
            }
            if op.outputs.is_empty() {
                return Err(format!("op {} has no outputs", op.name));
            }
        }
        // Producer map + order validity.
        let mut producer: Vec<Option<usize>> = vec![None; self.tensors.len()];
        for op in &self.ops {
            for &o in &op.outputs {
                if producer[o.0].is_some() {
                    return Err(format!("tensor {:?} has two producers", o));
                }
                producer[o.0] = Some(op.id.0);
            }
        }
        for op in &self.ops {
            for &inp in &op.inputs {
                let t = self.tensor(inp);
                match t.kind {
                    TensorKind::Input | TensorKind::Weight => {}
                    _ => match producer[inp.0] {
                        None => {
                            return Err(format!(
                                "op {} consumes unproduced tensor {}",
                                op.name, t.name
                            ))
                        }
                        Some(p) if p >= op.id.0 => {
                            return Err(format!(
                                "op {} (index {}) consumes tensor {} produced later (by op {})",
                                op.name, op.id.0, t.name, p
                            ))
                        }
                        _ => {}
                    },
                }
            }
        }
        if !is_valid_execution_order(self) {
            return Err("execution order is not a topological order".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_sizes() {
        assert_eq!(DType::F32.size_of(), 4);
        assert_eq!(DType::F16.size_of(), 2);
        assert_eq!(DType::U8.size_of(), 1);
        assert_eq!(DType::I32.size_of(), 4);
    }

    #[test]
    fn tensor_sizes() {
        let t = Tensor {
            id: TensorId(0),
            name: "t".into(),
            shape: vec![1, 112, 112, 32],
            dtype: DType::F32,
            kind: TensorKind::Intermediate,
        };
        assert_eq!(t.num_elements(), 112 * 112 * 32);
        assert_eq!(t.byte_size(), 4 * 112 * 112 * 32);
        assert_eq!(t.aligned_size(), 4 * 112 * 112 * 32); // already aligned
    }

    #[test]
    fn empty_graph_validates() {
        let g = Graph::default();
        assert!(g.validate().is_ok());
    }
}
