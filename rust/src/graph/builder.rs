//! Fluent construction of inference graphs with shape inference.
//!
//! The model zoo (`crate::models`) uses this builder to express each
//! evaluation network layer-by-layer; the builder infers every intermediate
//! tensor's shape, creates weight tensors for parametric ops, and keeps the
//! op list in execution order.

use super::{
    conv_out_dim, Activation, DType, Graph, Op, OpId, OpKind, Padding, PoolKind, Tensor,
    TensorId, TensorKind,
};

/// Builder for [`Graph`]. All `TensorId`s returned by builder methods refer
/// to the graph under construction.
pub struct GraphBuilder {
    graph: Graph,
    dtype: DType,
}

impl GraphBuilder {
    /// Start a new graph with the given name; intermediate tensors use
    /// `dtype` (the paper evaluates at F32).
    pub fn new(name: impl Into<String>, dtype: DType) -> Self {
        GraphBuilder {
            graph: Graph {
                name: name.into(),
                ..Default::default()
            },
            dtype,
        }
    }

    fn add_tensor(&mut self, name: String, shape: Vec<usize>, kind: TensorKind) -> TensorId {
        let id = TensorId(self.graph.tensors.len());
        self.graph.tensors.push(Tensor {
            id,
            name,
            shape,
            dtype: self.dtype,
            kind,
        });
        id
    }

    fn add_op(&mut self, name: String, kind: OpKind, inputs: Vec<TensorId>, out_shape: Vec<usize>) -> TensorId {
        let out = self.add_tensor(format!("{name}:out"), out_shape, TensorKind::Intermediate);
        let id = OpId(self.graph.ops.len());
        self.graph.ops.push(Op {
            id,
            name,
            kind,
            inputs,
            outputs: vec![out],
        });
        out
    }

    /// Shape accessor for a tensor already in the graph.
    pub fn shape(&self, t: TensorId) -> &[usize] {
        &self.graph.tensor(t).shape
    }

    /// Declare a network input `[n, h, w, c]` (or any rank).
    pub fn input(&mut self, name: impl Into<String>, shape: Vec<usize>) -> TensorId {
        let id = self.add_tensor(name.into(), shape, TensorKind::Input);
        self.graph.inputs.push(id);
        id
    }

    fn weight(&mut self, name: String, shape: Vec<usize>) -> TensorId {
        self.add_tensor(name, shape, TensorKind::Weight)
    }

    /// 2D convolution with bias, NHWC in, `[kh, kw, in_c, out_c]` weights.
    pub fn conv2d(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        out_c: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        activation: Activation,
    ) -> TensorId {
        self.conv2d_dilated(name, x, out_c, kernel, stride, padding, (1, 1), activation)
    }

    /// 2D convolution with explicit dilation (atrous, DeepLab).
    #[allow(clippy::too_many_arguments)]
    pub fn conv2d_dilated(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        out_c: usize,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        dilation: (usize, usize),
        activation: Activation,
    ) -> TensorId {
        let name = name.into();
        let (n, h, w, c) = self.nhwc(x);
        let oh = conv_out_dim(h, kernel.0, stride.0, dilation.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, dilation.1, padding);
        let wt = self.weight(format!("{name}:w"), vec![kernel.0, kernel.1, c, out_c]);
        let b = self.weight(format!("{name}:b"), vec![out_c]);
        self.add_op(
            name,
            OpKind::Conv2d {
                kernel,
                stride,
                padding,
                dilation,
                activation,
            },
            vec![x, wt, b],
            vec![n, oh, ow, out_c],
        )
    }

    /// Depthwise convolution (multiplier 1) with bias.
    pub fn dwconv2d(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        activation: Activation,
    ) -> TensorId {
        self.dwconv2d_dilated(name, x, kernel, stride, padding, (1, 1), activation)
    }

    /// Depthwise convolution with dilation.
    #[allow(clippy::too_many_arguments)]
    pub fn dwconv2d_dilated(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
        dilation: (usize, usize),
        activation: Activation,
    ) -> TensorId {
        let name = name.into();
        let (n, h, w, c) = self.nhwc(x);
        let oh = conv_out_dim(h, kernel.0, stride.0, dilation.0, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, dilation.1, padding);
        let wt = self.weight(format!("{name}:w"), vec![kernel.0, kernel.1, c, 1]);
        let b = self.weight(format!("{name}:b"), vec![c]);
        self.add_op(
            name,
            OpKind::DepthwiseConv2d {
                kernel,
                stride,
                padding,
                dilation,
                activation,
            },
            vec![x, wt, b],
            vec![n, oh, ow, c],
        )
    }

    /// Max/average pooling.
    pub fn pool2d(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        kind: PoolKind,
        kernel: (usize, usize),
        stride: (usize, usize),
        padding: Padding,
    ) -> TensorId {
        let (n, h, w, c) = self.nhwc(x);
        let oh = conv_out_dim(h, kernel.0, stride.0, 1, padding);
        let ow = conv_out_dim(w, kernel.1, stride.1, 1, padding);
        self.add_op(
            name.into(),
            OpKind::Pool2d {
                kind,
                kernel,
                stride,
                padding,
            },
            vec![x],
            vec![n, oh, ow, c],
        )
    }

    /// Global average pool to `[n, 1, 1, c]`.
    pub fn global_avg_pool(&mut self, name: impl Into<String>, x: TensorId) -> TensorId {
        let (n, _, _, c) = self.nhwc(x);
        self.add_op(name.into(), OpKind::GlobalAveragePool, vec![x], vec![n, 1, 1, c])
    }

    /// Residual add; shapes must match.
    pub fn add(
        &mut self,
        name: impl Into<String>,
        a: TensorId,
        b: TensorId,
        activation: Activation,
    ) -> TensorId {
        let sa = self.shape(a).to_vec();
        let sb = self.shape(b).to_vec();
        assert_eq!(sa, sb, "add: shape mismatch {sa:?} vs {sb:?}");
        self.add_op(name.into(), OpKind::Add { activation }, vec![a, b], sa)
    }

    /// Elementwise multiply; shapes must match.
    pub fn mul(&mut self, name: impl Into<String>, a: TensorId, b: TensorId) -> TensorId {
        let sa = self.shape(a).to_vec();
        assert_eq!(sa, self.shape(b), "mul: shape mismatch");
        self.add_op(name.into(), OpKind::Mul, vec![a, b], sa)
    }

    /// Concatenate along the last (channel) axis; all other dims must match.
    pub fn concat(&mut self, name: impl Into<String>, xs: &[TensorId]) -> TensorId {
        assert!(!xs.is_empty());
        let lead = self.shape(xs[0])[..self.shape(xs[0]).len() - 1].to_vec();
        let mut c_total = 0;
        for &x in xs {
            let s = self.shape(x);
            assert_eq!(&s[..s.len() - 1], &lead[..], "concat: leading-dim mismatch");
            c_total += s[s.len() - 1];
        }
        let mut out = lead;
        out.push(c_total);
        self.add_op(name.into(), OpKind::ConcatChannels, xs.to_vec(), out)
    }

    /// Fully connected with bias: `[n, in] x [in, out]`.
    pub fn fully_connected(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        out: usize,
        activation: Activation,
    ) -> TensorId {
        let name = name.into();
        let shape = self.shape(x).to_vec();
        let n = shape[0];
        let in_dim: usize = shape[1..].iter().product();
        let wt = self.weight(format!("{name}:w"), vec![in_dim, out]);
        let b = self.weight(format!("{name}:b"), vec![out]);
        self.add_op(
            name,
            OpKind::FullyConnected { activation },
            vec![x, wt, b],
            vec![n, out],
        )
    }

    /// Softmax over last axis.
    pub fn softmax(&mut self, name: impl Into<String>, x: TensorId) -> TensorId {
        let shape = self.shape(x).to_vec();
        self.add_op(name.into(), OpKind::Softmax, vec![x], shape)
    }

    /// Standalone ReLU (`max=None`) or ReLU6 (`max=Some(6.0)`).
    pub fn relu(&mut self, name: impl Into<String>, x: TensorId, max: Option<f32>) -> TensorId {
        let shape = self.shape(x).to_vec();
        self.add_op(name.into(), OpKind::Relu { max }, vec![x], shape)
    }

    /// Sigmoid.
    pub fn sigmoid(&mut self, name: impl Into<String>, x: TensorId) -> TensorId {
        let shape = self.shape(x).to_vec();
        self.add_op(name.into(), OpKind::Sigmoid, vec![x], shape)
    }

    /// Bilinear resize to `(h, w)`.
    pub fn resize_bilinear(&mut self, name: impl Into<String>, x: TensorId, out: (usize, usize)) -> TensorId {
        let (n, _, _, c) = self.nhwc(x);
        self.add_op(
            name.into(),
            OpKind::ResizeBilinear { out },
            vec![x],
            vec![n, out.0, out.1, c],
        )
    }

    /// Reshape to a new shape with the same element count.
    pub fn reshape(&mut self, name: impl Into<String>, x: TensorId, shape: Vec<usize>) -> TensorId {
        let old: usize = self.shape(x).iter().product();
        let new: usize = shape.iter().product();
        assert_eq!(old, new, "reshape: element count mismatch");
        self.add_op(name.into(), OpKind::Reshape, vec![x], shape)
    }

    /// Explicit spatial zero-pad.
    pub fn pad_spatial(
        &mut self,
        name: impl Into<String>,
        x: TensorId,
        before: (usize, usize),
        after: (usize, usize),
    ) -> TensorId {
        let (n, h, w, c) = self.nhwc(x);
        self.add_op(
            name.into(),
            OpKind::Pad { before, after },
            vec![x],
            vec![n, h + before.0 + after.0, w + before.1 + after.1, c],
        )
    }

    /// Mark `t` as a network output. Per the paper (Figure 1, tensor #8) the
    /// output tensor is *not* an intermediate tensor and is excluded from
    /// planning.
    pub fn mark_output(&mut self, t: TensorId) {
        let tensor = &mut self.graph.tensors[t.0];
        assert_eq!(tensor.kind, TensorKind::Intermediate, "output must be produced by an op");
        tensor.kind = TensorKind::Output;
        self.graph.outputs.push(t);
    }

    /// Finish: validate and return the graph.
    pub fn finish(self) -> Graph {
        let g = self.graph;
        if let Err(e) = g.validate() {
            panic!("graph {} failed validation: {e}", g.name);
        }
        g
    }

    fn nhwc(&self, t: TensorId) -> (usize, usize, usize, usize) {
        let s = self.shape(t);
        assert_eq!(s.len(), 4, "expected NHWC tensor, got shape {s:?}");
        (s[0], s[1], s[2], s[3])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_a_small_convnet() {
        let mut b = GraphBuilder::new("tiny", DType::F32);
        let x = b.input("x", vec![1, 8, 8, 3]);
        let c1 = b.conv2d("c1", x, 16, (3, 3), (2, 2), Padding::Same, Activation::Relu6);
        assert_eq!(b.shape(c1), &[1, 4, 4, 16]);
        let d1 = b.dwconv2d("d1", c1, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
        let p1 = b.conv2d("p1", d1, 16, (1, 1), (1, 1), Padding::Same, Activation::None);
        let r = b.add("res", c1, p1, Activation::None);
        let g1 = b.global_avg_pool("gap", r);
        let f = b.reshape("flat", g1, vec![1, 16]);
        let fc = b.fully_connected("fc", f, 10, Activation::None);
        let sm = b.softmax("sm", fc);
        b.mark_output(sm);
        let g = b.finish();
        assert_eq!(g.outputs.len(), 1);
        // conv weights + bias exist as Weight tensors
        assert!(g.weight_bytes() > 0);
        // output excluded from intermediates
        let inter: Vec<_> = g.intermediates().collect();
        assert!(inter.iter().all(|t| t.kind == TensorKind::Intermediate));
        assert_eq!(inter.len(), 7); // c1 d1 p1 res gap flat fc (sm is output)
    }

    #[test]
    #[should_panic]
    fn add_shape_mismatch_panics() {
        let mut b = GraphBuilder::new("bad", DType::F32);
        let x = b.input("x", vec![1, 8, 8, 3]);
        let c1 = b.conv2d("c1", x, 16, (3, 3), (2, 2), Padding::Same, Activation::None);
        let c2 = b.conv2d("c2", x, 8, (3, 3), (2, 2), Padding::Same, Activation::None);
        b.add("res", c1, c2, Activation::None);
    }
}
