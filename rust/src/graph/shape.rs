//! Shape inference helpers (TFLite conventions, NHWC).



/// Spatial padding scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Padding {
    /// Output spatial size = ceil(in / stride); zero-pads as needed.
    Same,
    /// No padding; output = floor((in - eff_kernel) / stride) + 1.
    Valid,
}

/// Output spatial dimension for a conv/pool along one axis.
///
/// `dilation` expands the effective kernel to `(k - 1) * d + 1`
/// (atrous convolution, used by DeepLab v3).
pub fn conv_out_dim(input: usize, kernel: usize, stride: usize, dilation: usize, pad: Padding) -> usize {
    let eff = (kernel - 1) * dilation + 1;
    match pad {
        Padding::Same => (input + stride - 1) / stride,
        Padding::Valid => {
            assert!(
                input >= eff,
                "VALID conv: input {input} smaller than effective kernel {eff}"
            );
            (input - eff) / stride + 1
        }
    }
}

/// Total zero padding inserted along one axis under SAME (TFLite formula);
/// returned as (before, after).
pub fn same_padding(input: usize, kernel: usize, stride: usize, dilation: usize) -> (usize, usize) {
    let eff = (kernel - 1) * dilation + 1;
    let out = (input + stride - 1) / stride;
    let total = ((out - 1) * stride + eff).saturating_sub(input);
    (total / 2, total - total / 2)
}

/// Convenience for executors: the *before* padding on (h, w) under SAME.
pub fn same_padding_pair(
    h: usize,
    w: usize,
    kernel: (usize, usize),
    stride: (usize, usize),
    dilation: (usize, usize),
) -> (usize, usize) {
    (
        same_padding(h, kernel.0, stride.0, dilation.0).0,
        same_padding(w, kernel.1, stride.1, dilation.1).0,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_halves_with_stride2() {
        assert_eq!(conv_out_dim(224, 3, 2, 1, Padding::Same), 112);
        assert_eq!(conv_out_dim(112, 3, 1, 1, Padding::Same), 112);
        assert_eq!(conv_out_dim(7, 3, 2, 1, Padding::Same), 4);
    }

    #[test]
    fn valid_shrinks() {
        assert_eq!(conv_out_dim(299, 3, 2, 1, Padding::Valid), 149);
        assert_eq!(conv_out_dim(149, 3, 1, 1, Padding::Valid), 147);
        assert_eq!(conv_out_dim(5, 5, 1, 1, Padding::Valid), 1);
    }

    #[test]
    fn dilation_expands_kernel() {
        // 3x3 kernel at dilation 2 behaves like 5x5.
        assert_eq!(
            conv_out_dim(33, 3, 1, 2, Padding::Valid),
            conv_out_dim(33, 5, 1, 1, Padding::Valid)
        );
        assert_eq!(conv_out_dim(33, 3, 1, 2, Padding::Same), 33);
    }

    #[test]
    fn same_padding_amounts() {
        assert_eq!(same_padding(224, 3, 2, 1), (0, 1));
        assert_eq!(same_padding(112, 3, 1, 1), (1, 1));
    }

    #[test]
    #[should_panic]
    fn valid_panics_when_kernel_too_big() {
        conv_out_dim(2, 3, 1, 1, Padding::Valid);
    }
}
