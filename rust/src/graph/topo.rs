//! Topological ordering of graphs.
//!
//! The paper fixes the operator execution order to one topological sort
//! (§3) and notes in §7.1 that choosing the sort to minimize footprint is
//! future work. We provide deterministic Kahn's-algorithm sorting (smallest
//! original index first — insertion order, the TFLite behaviour) so that
//! planner experiments are reproducible, plus an order validator.

use super::{Graph, OpId, TensorKind};
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Compute a deterministic topological order of the graph's ops.
///
/// Ties are broken by smallest op index, which reproduces insertion order
/// for graphs already stored topologically. Returns `None` if the graph has
/// a cycle.
pub fn topo_sort(graph: &Graph) -> Option<Vec<OpId>> {
    let n = graph.ops.len();
    // producer[t] = op producing tensor t
    let mut producer = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            producer[o.0] = op.id.0;
        }
    }
    let mut indegree = vec![0usize; n];
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n]; // producer op -> consumer ops
    for op in &graph.ops {
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            let p = producer[inp.0];
            if p != usize::MAX {
                consumers[p].push(op.id.0);
                indegree[op.id.0] += 1;
            }
        }
    }
    let mut heap: BinaryHeap<Reverse<usize>> = indegree
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d == 0)
        .map(|(i, _)| Reverse(i))
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(Reverse(i)) = heap.pop() {
        order.push(OpId(i));
        for &c in &consumers[i] {
            indegree[c] -= 1;
            if indegree[c] == 0 {
                heap.push(Reverse(c));
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// True if the graph's stored op order (ids 0..n) is a valid topological
/// order: every op's inputs are produced strictly earlier.
pub fn is_valid_execution_order(graph: &Graph) -> bool {
    let mut produced_at = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            produced_at[o.0] = op.id.0;
        }
    }
    for op in &graph.ops {
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            let p = produced_at[inp.0];
            if p == usize::MAX || p >= op.id.0 {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use crate::models::example_net;
    use super::*;

    #[test]
    fn example_net_is_topological() {
        let g = example_net();
        assert!(is_valid_execution_order(&g));
        let order = topo_sort(&g).expect("acyclic");
        // Stored order is already topological and ties break to insertion
        // order, so the sort must be the identity.
        let ids: Vec<usize> = order.iter().map(|o| o.0).collect();
        assert_eq!(ids, (0..g.ops.len()).collect::<Vec<_>>());
    }
}
