//! Topological ordering of graphs.
//!
//! The paper fixes the operator execution order to one topological sort
//! (§3) and notes in §7.1 that choosing the sort to minimize footprint is
//! future work. We provide deterministic Kahn's-algorithm sorting (smallest
//! original index first — insertion order, the TFLite behaviour) so that
//! planner experiments are reproducible, plus an order validator.

use super::{Graph, OpId, TensorKind};
use std::collections::BinaryHeap;
use std::cmp::Reverse;

/// Compute a deterministic topological order of the graph's ops.
///
/// Ties are broken by smallest op index, which reproduces insertion order
/// for graphs already stored topologically. Returns `None` if the graph has
/// a cycle.
pub fn topo_sort(graph: &Graph) -> Option<Vec<OpId>> {
    let n = graph.ops.len();
    // producer[t] = op producing tensor t
    let mut producer = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            producer[o.0] = op.id.0;
        }
    }
    let mut indegree = vec![0usize; n];
    let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); n]; // producer op -> consumer ops
    for op in &graph.ops {
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            let p = producer[inp.0];
            if p != usize::MAX {
                consumers[p].push(op.id.0);
                indegree[op.id.0] += 1;
            }
        }
    }
    let mut heap: BinaryHeap<Reverse<usize>> = indegree
        .iter()
        .enumerate()
        .filter(|&(_, &d)| d == 0)
        .map(|(i, _)| Reverse(i))
        .collect();
    let mut order = Vec::with_capacity(n);
    while let Some(Reverse(i)) = heap.pop() {
        order.push(OpId(i));
        for &c in &consumers[i] {
            indegree[c] -= 1;
            if indegree[c] == 0 {
                heap.push(Reverse(c));
            }
        }
    }
    (order.len() == n).then_some(order)
}

/// Partition the ops into **level sets**: `level(op)` is the length of the
/// longest producer chain feeding it, so every op in level *k* depends only
/// on ops in levels `< k`. Ops within one level are mutually independent in
/// the dataflow sense and are the candidates the parallel executor
/// ([`crate::exec::Executor`]) dispatches across worker threads — after an
/// additional arena-aliasing check, since dataflow independence alone does
/// not rule out two ops writing overlapping planned offsets.
///
/// The returned vector is indexed by level; within a level, op ids ascend
/// (deterministic). Returns `None` if the graph has a cycle. For a graph
/// stored in topological order, concatenating the levels yields a valid
/// execution order.
pub fn topo_levels(graph: &Graph) -> Option<Vec<Vec<OpId>>> {
    let order = topo_sort(graph)?;
    let mut producer = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            producer[o.0] = op.id.0;
        }
    }
    let mut level = vec![0usize; graph.ops.len()];
    let mut depth = 0usize;
    for &id in &order {
        let op = graph.op(id);
        let mut lv = 0usize;
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            let p = producer[inp.0];
            if p != usize::MAX {
                lv = lv.max(level[p] + 1);
            }
        }
        level[id.0] = lv;
        depth = depth.max(lv + 1);
    }
    let mut levels: Vec<Vec<OpId>> = vec![Vec::new(); depth];
    // Iterate by ascending op id so each level lists ids in order.
    for (i, &lv) in level.iter().enumerate() {
        levels[lv].push(OpId(i));
    }
    Some(levels)
}

/// True if the graph's stored op order (ids 0..n) is a valid topological
/// order: every op's inputs are produced strictly earlier.
pub fn is_valid_execution_order(graph: &Graph) -> bool {
    let mut produced_at = vec![usize::MAX; graph.tensors.len()];
    for op in &graph.ops {
        for &o in &op.outputs {
            produced_at[o.0] = op.id.0;
        }
    }
    for op in &graph.ops {
        for &inp in &op.inputs {
            let t = graph.tensor(inp);
            if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                continue;
            }
            let p = produced_at[inp.0];
            if p == usize::MAX || p >= op.id.0 {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use crate::models::example_net;
    use super::*;

    #[test]
    fn example_net_is_topological() {
        let g = example_net();
        assert!(is_valid_execution_order(&g));
        let order = topo_sort(&g).expect("acyclic");
        // Stored order is already topological and ties break to insertion
        // order, so the sort must be the identity.
        let ids: Vec<usize> = order.iter().map(|o| o.0).collect();
        assert_eq!(ids, (0..g.ops.len()).collect::<Vec<_>>());
    }

    #[test]
    fn levels_partition_ops_and_respect_dependencies() {
        for g in crate::models::all_zoo() {
            let levels = topo_levels(&g).expect("acyclic");
            // Partition: every op appears exactly once.
            let mut seen = vec![false; g.ops.len()];
            for lv in &levels {
                assert!(!lv.is_empty(), "{}: empty level", g.name);
                for &id in lv {
                    assert!(!seen[id.0], "{}: op {} in two levels", g.name, id.0);
                    seen[id.0] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "{}: op missing from levels", g.name);
            // Dependencies: every activation input of a level-k op is
            // produced at a strictly earlier level.
            let mut level_of = vec![usize::MAX; g.ops.len()];
            for (k, lv) in levels.iter().enumerate() {
                for &id in lv {
                    level_of[id.0] = k;
                }
            }
            let mut producer = vec![usize::MAX; g.tensors.len()];
            for op in &g.ops {
                for &o in &op.outputs {
                    producer[o.0] = op.id.0;
                }
            }
            for op in &g.ops {
                for &inp in &op.inputs {
                    let t = g.tensor(inp);
                    if matches!(t.kind, TensorKind::Input | TensorKind::Weight) {
                        continue;
                    }
                    let p = producer[inp.0];
                    if p != usize::MAX {
                        assert!(
                            level_of[p] < level_of[op.id.0],
                            "{}: op {} not after its producer {}",
                            g.name,
                            op.id.0,
                            p
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn inception_has_wide_levels() {
        // Inception's parallel towers must surface as levels with >1 op —
        // otherwise the parallel executor has nothing to run concurrently.
        let g = crate::models::inception_v3();
        let levels = topo_levels(&g).expect("acyclic");
        assert!(
            levels.iter().any(|lv| lv.len() > 1),
            "inception_v3 levels are all singletons"
        );
    }
}
