//! Operator nodes of the graph IR.

use super::{Padding, TensorId};


/// Unique id of an op within its graph; equals the op's position in the
/// fixed execution order (the operator *index* of the paper's §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct OpId(pub usize);

/// Fused activation function, TFLite-style (fused activations do not create
/// extra intermediate tensors).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Activation {
    /// No fused activation.
    #[default]
    None,
    /// `max(x, 0)`.
    Relu,
    /// `clamp(x, 0, 6)`.
    Relu6,
}

/// Pooling flavour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PoolKind {
    /// Max pooling.
    Max,
    /// Average pooling.
    Average,
}

/// The operator set: enough to express the paper's six evaluation networks
/// (MobileNet v1/v2, DeepLab v3, Inception v3, PoseNet, BlazeFace) and to be
/// executed by `exec::Executor`.
#[derive(Debug, Clone, PartialEq)]
pub enum OpKind {
    /// 2D convolution, NHWC, weights `[kh, kw, in_c, out_c]`.
    Conv2d {
        /// Kernel spatial size `(kh, kw)`.
        kernel: (usize, usize),
        /// Stride `(sh, sw)`.
        stride: (usize, usize),
        /// Padding scheme.
        padding: Padding,
        /// Dilation `(dh, dw)` (atrous convolution).
        dilation: (usize, usize),
        /// Fused activation.
        activation: Activation,
    },
    /// Depthwise 2D convolution, multiplier 1, weights `[kh, kw, c, 1]`.
    DepthwiseConv2d {
        /// Kernel spatial size `(kh, kw)`.
        kernel: (usize, usize),
        /// Stride `(sh, sw)`.
        stride: (usize, usize),
        /// Padding scheme.
        padding: Padding,
        /// Dilation `(dh, dw)`.
        dilation: (usize, usize),
        /// Fused activation.
        activation: Activation,
    },
    /// Spatial pooling.
    Pool2d {
        /// Max or average.
        kind: PoolKind,
        /// Window spatial size `(kh, kw)`.
        kernel: (usize, usize),
        /// Stride `(sh, sw)`.
        stride: (usize, usize),
        /// Padding scheme.
        padding: Padding,
    },
    /// Global average pool to `[N, 1, 1, C]` (a.k.a. `MEAN` over H,W).
    GlobalAveragePool,
    /// Elementwise binary add (residual connections).
    Add {
        /// Fused activation.
        activation: Activation,
    },
    /// Elementwise binary multiply.
    Mul,
    /// Concatenation along the channel axis (Inception blocks).
    ConcatChannels,
    /// Fully connected: input `[N, in]`, weights `[in, out]`.
    FullyConnected {
        /// Fused activation.
        activation: Activation,
    },
    /// Softmax over the last axis.
    Softmax,
    /// Standalone ReLU / ReLU6 (when not fusable).
    Relu {
        /// Upper clamp (`Some(6.0)` for ReLU6, `None` for plain ReLU).
        max: Option<f32>,
    },
    /// Logistic sigmoid.
    Sigmoid,
    /// Nearest/bilinear resize to a fixed spatial size (DeepLab decoder).
    ResizeBilinear {
        /// Output spatial size `(oh, ow)`.
        out: (usize, usize),
    },
    /// Reshape (no data movement in planning terms, but produces a new
    /// intermediate tensor in TFLite graphs).
    Reshape,
    /// Explicit zero padding of spatial dims (BlazeFace-style channel pad is
    /// modelled via Conv2d in the zoo).
    Pad {
        /// Rows/columns added before `(top, left)`.
        before: (usize, usize),
        /// Rows/columns added after `(bottom, right)`.
        after: (usize, usize),
    },
    /// Mean-subtract/scale style pre-processing treated as elementwise.
    Elementwise {
        /// Mnemonic reported by traces.
        name: &'static str,
    },
}

impl OpKind {
    /// Short mnemonic, used by reports and traces.
    pub fn mnemonic(&self) -> &'static str {
        match self {
            OpKind::Conv2d { .. } => "CONV_2D",
            OpKind::DepthwiseConv2d { .. } => "DW_CONV_2D",
            OpKind::Pool2d { kind: PoolKind::Max, .. } => "MAX_POOL_2D",
            OpKind::Pool2d { kind: PoolKind::Average, .. } => "AVG_POOL_2D",
            OpKind::GlobalAveragePool => "MEAN",
            OpKind::Add { .. } => "ADD",
            OpKind::Mul => "MUL",
            OpKind::ConcatChannels => "CONCATENATION",
            OpKind::FullyConnected { .. } => "FULLY_CONNECTED",
            OpKind::Softmax => "SOFTMAX",
            OpKind::Relu { .. } => "RELU",
            OpKind::Sigmoid => "LOGISTIC",
            OpKind::ResizeBilinear { .. } => "RESIZE_BILINEAR",
            OpKind::Reshape => "RESHAPE",
            OpKind::Pad { .. } => "PAD",
            OpKind::Elementwise { name } => name,
        }
    }
}

/// One operator node.
#[derive(Debug, Clone)]
pub struct Op {
    /// Position in the fixed execution order.
    pub id: OpId,
    /// Human-readable name (layer name in the zoo models).
    pub name: String,
    /// What the op computes.
    pub kind: OpKind,
    /// Data inputs (activations) followed by weight tensors, if any.
    pub inputs: Vec<TensorId>,
    /// Output tensors (exactly one for every kind the executor runs).
    pub outputs: Vec<TensorId>,
}

impl Op {
    /// Approximate multiply-accumulate count for profiling/roofline notes.
    pub fn flops(&self, out_elems: usize, in_c: usize) -> usize {
        match &self.kind {
            OpKind::Conv2d { kernel, .. } => 2 * out_elems * kernel.0 * kernel.1 * in_c,
            OpKind::DepthwiseConv2d { kernel, .. } => 2 * out_elems * kernel.0 * kernel.1,
            OpKind::FullyConnected { .. } => 2 * out_elems * in_c,
            _ => out_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mnemonics_are_stable() {
        let k = OpKind::Conv2d {
            kernel: (3, 3),
            stride: (1, 1),
            padding: Padding::Same,
            dilation: (1, 1),
            activation: Activation::Relu6,
        };
        assert_eq!(k.mnemonic(), "CONV_2D");
        assert_eq!(OpKind::Softmax.mnemonic(), "SOFTMAX");
        assert_eq!(
            OpKind::Pool2d {
                kind: PoolKind::Average,
                kernel: (2, 2),
                stride: (2, 2),
                padding: Padding::Valid
            }
            .mnemonic(),
            "AVG_POOL_2D"
        );
    }

    #[test]
    fn conv_flops() {
        let op = Op {
            id: OpId(0),
            name: "c".into(),
            kind: OpKind::Conv2d {
                kernel: (3, 3),
                stride: (1, 1),
                padding: Padding::Same,
                dilation: (1, 1),
                activation: Activation::None,
            },
            inputs: vec![],
            outputs: vec![],
        };
        assert_eq!(op.flops(100, 8), 2 * 100 * 9 * 8);
    }
}
