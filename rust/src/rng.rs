//! Small deterministic PRNG (SplitMix64) used for weight/input synthesis,
//! workload generation, and property tests.
//!
//! The vendored offline registry has no `rand`; this is the standard
//! SplitMix64 generator (Steele et al., "Fast splittable pseudorandom number
//! generators"), which is more than adequate for synthesizing test data —
//! everything in this crate that consumes randomness takes an explicit seed
//! so runs are reproducible.

/// SplitMix64 PRNG.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Seeded generator; equal seeds give equal streams.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero.
    pub fn next_below(&mut self, bound: usize) -> usize {
        assert!(bound > 0);
        (self.next_u64() % bound as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    pub fn next_range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        lo + self.next_below(hi - lo + 1)
    }

    /// Uniform f32 in `[-scale, scale)`.
    pub fn next_f32(&mut self, scale: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32; // [0,1)
        (u * 2.0 - 1.0) * scale
    }

    /// Fill a slice with uniform values in `[-scale, scale)`.
    pub fn fill_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.next_f32(scale);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_equal_seeds() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respect_bounds() {
        let mut r = SplitMix64::new(7);
        for _ in 0..1000 {
            let v = r.next_range(3, 9);
            assert!((3..=9).contains(&v));
            let f = r.next_f32(2.0);
            assert!((-2.0..2.0).contains(&f));
        }
    }

    #[test]
    fn splitmix_reference_vector() {
        // Reference values for seed 1234567 from the SplitMix64 paper's
        // reference implementation.
        let mut r = SplitMix64::new(1234567);
        assert_eq!(r.next_u64(), 6457827717110365317);
        assert_eq!(r.next_u64(), 3203168211198807973);
    }
}
