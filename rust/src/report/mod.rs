//! Table rendering for the paper's evaluation artifacts.
//!
//! `benches/table1_shared_objects.rs`, `benches/table2_offset_calculation.rs`
//! and the CLI all print through this module so EXPERIMENTS.md, the bench
//! output, and `tensorarena table1` agree byte-for-byte.

use crate::models;
use crate::planner::registry;
use crate::records::UsageRecords;
use std::time::Instant;

/// Bytes per MiB; the paper's tables are in MiB (its "MB" for MobileNet v1's
/// lower bound, 4.594, equals 4,816,896 bytes = 4.594 * 2^20).
pub const MIB: f64 = 1024.0 * 1024.0;

/// One rendered table.
pub struct Table {
    /// Table heading.
    pub title: String,
    /// Column headers (network names).
    pub columns: Vec<String>,
    /// `(row label, one value per column)` rows.
    pub rows: Vec<(String, Vec<f64>)>,
}

impl Table {
    /// Render with the best value per column bolded with `*`, mirroring the
    /// paper's "best results in bold". Baseline rows (Lower Bound, Naive)
    /// are excluded from the best-of comparison, as in the paper.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n", self.title));
        let name_w = self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        out.push_str(&format!("{:name_w$} ", "Strategy"));
        for c in &self.columns {
            out.push_str(&format!("{c:>14} "));
        }
        out.push('\n');
        // best per column among non-baseline rows
        let is_baseline = |n: &str| n == "Lower Bound" || n == "Naive";
        let mut best = vec![f64::INFINITY; self.columns.len()];
        for (name, vals) in &self.rows {
            if is_baseline(name) {
                continue;
            }
            for (b, &v) in best.iter_mut().zip(vals.iter()) {
                if v < *b {
                    *b = v;
                }
            }
        }
        for (name, vals) in &self.rows {
            out.push_str(&format!("{name:name_w$} "));
            for (i, &v) in vals.iter().enumerate() {
                let mark = if !is_baseline(name) && (v - best[i]).abs() < 1e-9 {
                    "*"
                } else {
                    " "
                };
                out.push_str(&format!("{:>13.3}{mark}", v));
            }
            out.push('\n');
        }
        out
    }
}

/// Regenerate Table 1 (Shared Objects, MiB) over the six zoo networks.
pub fn table1() -> Table {
    let zoo = models::all_zoo();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    let recs: Vec<UsageRecords> = zoo.iter().map(UsageRecords::from_graph).collect();
    for strat in registry::shared_strategies() {
        if strat.name() == "Naive" {
            continue; // rendered from records below, like the paper's layout
        }
        let mut vals = Vec::new();
        for r in &recs {
            let plan = strat.plan(r);
            plan.validate(r).expect("infeasible plan");
            vals.push(plan.total_size() as f64 / MIB);
        }
        rows.push((strat.name().to_string(), vals));
    }
    rows.push((
        "Lower Bound".into(),
        recs.iter()
            .map(|r| r.profiles().shared_objects_lower_bound() as f64 / MIB)
            .collect(),
    ));
    rows.push((
        "Naive".into(),
        recs.iter().map(|r| r.naive_total() as f64 / MIB).collect(),
    ));
    Table {
        title: "Table 1: memory footprint of Shared Objects strategies (MiB)".into(),
        columns: models::ZOO.iter().map(|s| s.to_string()).collect(),
        rows,
    }
}

/// Regenerate Table 2 (Offset Calculation, MiB) over the six zoo networks.
pub fn table2() -> Table {
    let zoo = models::all_zoo();
    let recs: Vec<UsageRecords> = zoo.iter().map(UsageRecords::from_graph).collect();
    let mut rows: Vec<(String, Vec<f64>)> = Vec::new();
    for strat in registry::offset_strategies() {
        if strat.name() == "Naive" {
            continue;
        }
        let mut vals = Vec::new();
        for r in &recs {
            let plan = strat.plan(r);
            plan.validate(r).expect("infeasible plan");
            vals.push(plan.total_size() as f64 / MIB);
        }
        rows.push((strat.name().to_string(), vals));
    }
    rows.push((
        "Lower Bound".into(),
        recs.iter()
            .map(|r| r.profiles().offset_lower_bound() as f64 / MIB)
            .collect(),
    ));
    rows.push((
        "Naive".into(),
        recs.iter().map(|r| r.naive_total() as f64 / MIB).collect(),
    ));
    Table {
        title: "Table 2: memory footprint of Offset Calculation strategies (MiB)".into(),
        columns: models::ZOO.iter().map(|s| s.to_string()).collect(),
        rows,
    }
}

/// ASCII rendering of an offset plan as a memory-vs-time chart (the way
/// Figure 6 draws allocations): rows are arena bands, columns are operator
/// timestamps, cells show which tensor occupies the band while live.
///
/// `bands` controls vertical resolution. Only graphs with ≤ 62 records get
/// distinct glyphs; larger plans reuse glyphs (layout stays exact).
pub fn render_offset_timeline(records: &UsageRecords, plan: &crate::planner::OffsetPlan, bands: usize) -> String {
    const GLYPHS: &[u8] = b"0123456789abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ";
    let n_ops = records.num_ops;
    if plan.total == 0 || n_ops == 0 {
        return String::from("(empty plan)\n");
    }
    let band_size = (plan.total + bands - 1) / bands;
    let mut grid = vec![vec![b'.'; n_ops]; bands];
    for r in &records.records {
        let glyph = GLYPHS[r.id % GLYPHS.len()];
        let lo = plan.offsets[r.id] / band_size;
        let hi = ((plan.offsets[r.id] + r.size).saturating_sub(1)) / band_size;
        for band in lo..=hi.min(bands - 1) {
            for t in r.first_op..=r.last_op {
                grid[band][t] = glyph;
            }
        }
    }
    let mut out = String::new();
    out.push_str(&format!(
        "arena {} B, {} ops, 1 row = {} B (top = high addresses)\n",
        plan.total, n_ops, band_size
    ));
    for band in (0..bands).rev() {
        out.push_str(&format!("{:>10} |", band * band_size));
        out.push_str(std::str::from_utf8(&grid[band]).unwrap());
        out.push_str("|\n");
    }
    out.push_str(&format!("{:>10} +{}+\n", "op", "-".repeat(n_ops)));
    out
}

/// Simple timing helper used by the hand-rolled benches (criterion is not in
/// the offline registry): median + min of `iters` runs.
pub fn time_it<F: FnMut()>(iters: usize, mut f: F) -> (std::time::Duration, std::time::Duration) {
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    (samples[samples.len() / 2], samples[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_marks_best() {
        let t = Table {
            title: "t".into(),
            columns: vec!["a".into(), "b".into()],
            rows: vec![
                ("x".into(), vec![1.0, 5.0]),
                ("y".into(), vec![2.0, 3.0]),
                ("Naive".into(), vec![0.5, 0.5]),
            ],
        };
        let s = t.render();
        assert!(s.contains("1.000*"));
        assert!(s.contains("3.000*"));
        // Naive excluded from best marking
        assert!(!s.contains("0.500*"));
    }

    #[test]
    fn timeline_renders_example_plan() {
        use crate::planner::OffsetPlanner;
        let recs = crate::models::example_records();
        let plan = crate::planner::offset::GreedyBySize.plan(&recs);
        let s = render_offset_timeline(&recs, &plan, 8);
        assert!(s.contains("arena 114 B"));
        // 8 bands + header + axis = 10 lines
        assert_eq!(s.lines().count(), 10);
        // tensor 5 (size 64 at offset 0) occupies the bottom band at op 4
        let bottom = s.lines().nth(8).unwrap();
        assert!(bottom.contains('5'));
    }

    #[test]
    fn timeline_empty_plan() {
        let recs = crate::records::UsageRecords::from_triples(&[]);
        let plan = crate::planner::OffsetPlan { offsets: vec![], total: 0 };
        assert_eq!(render_offset_timeline(&recs, &plan, 4), "(empty plan)\n");
    }

    #[test]
    fn time_it_returns_ordered_stats() {
        let (med, min) = time_it(5, || std::thread::sleep(std::time::Duration::from_micros(50)));
        assert!(min <= med);
    }
}
