//! MobileNet v2 (Sandler et al. 2018), 224×224×3, width multiplier 1.0 —
//! Table 1/2 column 2.
//!
//! The interesting planning structure here is the *inverted residual*: the
//! 6×-expanded tensors (e.g. 56×56×144) dominate breadth while the
//! bottleneck tensors live long across the residual add — the combination
//! the paper credits for Greedy by Breadth beating Greedy by Size on this
//! network (Table 1).

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding, TensorId};

/// `(expansion t, out_channels c, repeats n, first_stride s)` per the
/// MobileNet v2 paper, Table 2.
const BLOCKS: [(usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1),
    (6, 24, 2, 2),
    (6, 32, 3, 2),
    (6, 64, 4, 2),
    (6, 96, 3, 1),
    (6, 160, 3, 2),
    (6, 320, 1, 1),
];

/// One inverted-residual block; returns the new feature map.
pub(crate) fn inverted_residual(
    b: &mut GraphBuilder,
    name: &str,
    x: TensorId,
    expansion: usize,
    out_c: usize,
    stride: usize,
    dilation: usize,
) -> TensorId {
    let in_c = b.shape(x)[3];
    let mut h = x;
    if expansion != 1 {
        h = b.conv2d(
            format!("{name}/expand"),
            h,
            in_c * expansion,
            (1, 1),
            (1, 1),
            Padding::Same,
            Activation::Relu6,
        );
    }
    h = b.dwconv2d_dilated(
        format!("{name}/dw"),
        h,
        (3, 3),
        (stride, stride),
        Padding::Same,
        (dilation, dilation),
        Activation::Relu6,
    );
    // Linear bottleneck: no activation on the projection.
    h = b.conv2d(
        format!("{name}/project"),
        h,
        out_c,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::None,
    );
    if stride == 1 && in_c == out_c {
        h = b.add(format!("{name}/add"), x, h, Activation::None);
    }
    h
}

/// Build the MobileNet v2 backbone up to the 320-channel bottleneck.
/// `input_hw` lets DeepLab reuse it at 257×257; `output_stride` of 16
/// dilates the final stage instead of striding (DeepLab's atrous trick);
/// 32 is the classification default.
pub(crate) fn v2_backbone(b: &mut GraphBuilder, input_hw: usize, output_stride: usize) -> TensorId {
    assert!(output_stride == 32 || output_stride == 16);
    let x = b.input("input", vec![1, input_hw, input_hw, 3]);
    let mut h = b.conv2d(
        "conv1",
        x,
        32,
        (3, 3),
        (2, 2),
        Padding::Same,
        Activation::Relu6,
    );
    let mut current_stride = 2;
    let mut dilation = 1;
    for (bi, &(t, c, n, s)) in BLOCKS.iter().enumerate() {
        for r in 0..n {
            let mut stride = if r == 0 { s } else { 1 };
            // Convert stride to dilation once the output stride is reached.
            if stride == 2 && current_stride * 2 > output_stride {
                stride = 1;
                dilation *= 2;
            } else if stride == 2 {
                current_stride *= 2;
            }
            h = inverted_residual(
                b,
                &format!("block{}_{}", bi + 1, r + 1),
                h,
                t,
                c,
                stride,
                dilation,
            );
        }
    }
    h
}

/// Build MobileNet v2 classifier at batch 1, f32.
pub fn mobilenet_v2() -> Graph {
    let mut b = GraphBuilder::new("mobilenet_v2", DType::F32);
    let h = v2_backbone(&mut b, 224, 32);
    let head = b.conv2d(
        "conv_head",
        h,
        1280,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::Relu6,
    );
    let g = b.global_avg_pool("avg_pool", head);
    let flat = b.reshape("flatten", g, vec![1, 1280]);
    let logits = b.fully_connected("fc", flat, 1001, Activation::None);
    let probs = b.softmax("softmax", logits);
    b.mark_output(probs);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = mobilenet_v2();
        let recs = UsageRecords::from_graph(&g);
        assert!(recs.len() > 60, "v2 has {} intermediates", recs.len());
        assert_eq!(g.tensor(g.outputs[0]).shape, vec![1, 1001]);
        // Residual adds exist.
        assert!(g.ops.iter().any(|o| o.name.ends_with("/add")));
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper Table 1: Naive = 26.313 MiB.
        let g = mobilenet_v2();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (naive - 26.313).abs() / 26.313 < 0.10,
            "naive = {naive:.3} MiB, paper says 26.313"
        );
    }

    #[test]
    fn lower_bound_is_near_paper() {
        // Paper Table 2 lower bound: 5.742 MiB.
        let g = mobilenet_v2();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (lb - 5.742).abs() / 5.742 < 0.10,
            "offset lower bound = {lb:.4} MiB, paper says 5.742"
        );
    }
}
