//! PoseNet (Kendall et al. 2015), 224×224×3 — Table 1/2 column 5.
//!
//! PoseNet is GoogLeNet (Inception v1) with the classifier replaced by a
//! 6-DoF camera-pose regression head (2048-wide FC feeding a 3-vector
//! position and 4-vector orientation). The backbone's inception modules are
//! what the planner sees; the pose head is tiny.

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding, PoolKind, TensorId};

const RELU: Activation = Activation::Relu;

/// GoogLeNet inception module: `(c1, c3r, c3, c5r, c5, pp)`.
fn inception(
    b: &mut GraphBuilder,
    n: &str,
    x: TensorId,
    cfg: (usize, usize, usize, usize, usize, usize),
) -> TensorId {
    let (c1, c3r, c3, c5r, c5, pp) = cfg;
    let b1 = b.conv2d(format!("{n}/1x1"), x, c1, (1, 1), (1, 1), Padding::Same, RELU);
    let b3 = b.conv2d(format!("{n}/3x3r"), x, c3r, (1, 1), (1, 1), Padding::Same, RELU);
    let b3 = b.conv2d(format!("{n}/3x3"), b3, c3, (3, 3), (1, 1), Padding::Same, RELU);
    let b5 = b.conv2d(format!("{n}/5x5r"), x, c5r, (1, 1), (1, 1), Padding::Same, RELU);
    let b5 = b.conv2d(format!("{n}/5x5"), b5, c5, (5, 5), (1, 1), Padding::Same, RELU);
    let bp = b.pool2d(format!("{n}/pool"), x, PoolKind::Max, (3, 3), (1, 1), Padding::Same);
    let bp = b.conv2d(format!("{n}/poolproj"), bp, pp, (1, 1), (1, 1), Padding::Same, RELU);
    b.concat(format!("{n}/concat"), &[b1, b3, b5, bp])
}

/// Build PoseNet at batch 1, f32.
pub fn posenet() -> Graph {
    let mut b = GraphBuilder::new("posenet", DType::F32);
    let x = b.input("input", vec![1, 224, 224, 3]);
    let mut h = b.conv2d("conv1", x, 64, (7, 7), (2, 2), Padding::Same, RELU); // 112
    h = b.pool2d("pool1", h, PoolKind::Max, (3, 3), (2, 2), Padding::Same); // 56
    h = b.conv2d("conv2r", h, 64, (1, 1), (1, 1), Padding::Same, RELU);
    h = b.conv2d("conv2", h, 192, (3, 3), (1, 1), Padding::Same, RELU);
    h = b.pool2d("pool2", h, PoolKind::Max, (3, 3), (2, 2), Padding::Same); // 28
    h = inception(&mut b, "3a", h, (64, 96, 128, 16, 32, 32)); // 256
    h = inception(&mut b, "3b", h, (128, 128, 192, 32, 96, 64)); // 480
    h = b.pool2d("pool3", h, PoolKind::Max, (3, 3), (2, 2), Padding::Same); // 14
    h = inception(&mut b, "4a", h, (192, 96, 208, 16, 48, 64)); // 512
    h = inception(&mut b, "4b", h, (160, 112, 224, 24, 64, 64)); // 512
    h = inception(&mut b, "4c", h, (128, 128, 256, 24, 64, 64)); // 512
    h = inception(&mut b, "4d", h, (112, 144, 288, 32, 64, 64)); // 528
    h = inception(&mut b, "4e", h, (256, 160, 320, 32, 128, 128)); // 832
    h = b.pool2d("pool4", h, PoolKind::Max, (3, 3), (2, 2), Padding::Same); // 7
    h = inception(&mut b, "5a", h, (256, 160, 320, 32, 128, 128)); // 832
    h = inception(&mut b, "5b", h, (384, 192, 384, 48, 128, 128)); // 1024
    let g = b.global_avg_pool("avg_pool", h);
    let flat = b.reshape("flatten", g, vec![1, 1024]);
    // Pose regression head (Kendall 2015 §3): FC-2048 then 3+4 outputs.
    let feat = b.fully_connected("fc_pose", flat, 2048, RELU);
    let xyz = b.fully_connected("fc_xyz", feat, 3, Activation::None);
    let wpqr = b.fully_connected("fc_wpqr", feat, 4, Activation::None);
    b.mark_output(xyz);
    b.mark_output(wpqr);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = posenet();
        assert_eq!(g.outputs.len(), 2);
        let gap = g.ops.iter().find(|o| o.name == "avg_pool").unwrap();
        assert_eq!(g.tensor(gap.inputs[0]).shape, vec![1, 7, 7, 1024]);
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper: Naive = 28.556 MiB. Our GoogLeNet reconstruction fuses
        // ReLU/LRN the way TFLite would today (22.4 MiB); the paper's
        // converter kept more standalone tensors. Same order, documented in
        // EXPERIMENTS.md; assert the reconstruction window.
        let g = posenet();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (18.0..32.0).contains(&naive),
            "naive = {naive:.3} MiB, expected ~22 (paper graph: 28.556)"
        );
    }

    #[test]
    fn lower_bound_is_near_paper() {
        // Paper Table 2 lower bound: 6.271 MiB; with fused activations the
        // widest profile is conv1+pool1 = 3.83 MiB. The *relational* Table-2
        // claims are what EXPERIMENTS.md checks; here we pin our own value
        // so regressions are caught.
        let g = posenet();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (lb - 3.828).abs() < 0.05,
            "offset lower bound = {lb:.4} MiB, expected 3.828 (paper graph: 6.271)"
        );
    }
}
