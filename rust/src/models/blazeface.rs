//! BlazeFace (Bazarevsky et al. 2019), 128×128×3 — Table 1/2 column 6.
//!
//! The smallest zoo member: 5×5 depthwise "blaze blocks" feeding a
//! two-scale SSD-style anchor head. The public paper specifies the block
//! pattern but not every converted-graph detail (which adds/pads survive
//! TFLite conversion), so this reconstruction targets the paper's *scale*
//! (naive ≈ 2.7 MiB): stride-2 blocks drop their residual (the channel-pad
//! shortcut fuses away), same-shape blocks keep a residual add with fused
//! ReLU. Paper-vs-ours absolute deltas are tabulated in EXPERIMENTS.md.

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding, TensorId};

/// Single blaze block: dw5×5 → pw1×1 (+ residual add when shapes allow).
fn blaze_block(b: &mut GraphBuilder, n: &str, x: TensorId, out_c: usize, stride: usize) -> TensorId {
    let in_c = b.shape(x)[3];
    let dw = b.dwconv2d(
        format!("{n}/dw"),
        x,
        (5, 5),
        (stride, stride),
        Padding::Same,
        Activation::None,
    );
    let act = if stride == 1 && in_c == out_c {
        Activation::None
    } else {
        Activation::Relu
    };
    let pw = b.conv2d(format!("{n}/pw"), dw, out_c, (1, 1), (1, 1), Padding::Same, act);
    if stride == 1 && in_c == out_c {
        b.add(format!("{n}/add"), x, pw, Activation::Relu)
    } else {
        pw
    }
}

/// Double blaze block: dw→pw(bottleneck 24)→dw→pw(out_c), residual when
/// shapes allow.
fn double_blaze_block(b: &mut GraphBuilder, n: &str, x: TensorId, out_c: usize, stride: usize) -> TensorId {
    let in_c = b.shape(x)[3];
    let dw1 = b.dwconv2d(
        format!("{n}/dw1"),
        x,
        (5, 5),
        (stride, stride),
        Padding::Same,
        Activation::None,
    );
    let pw1 = b.conv2d(format!("{n}/pw1"), dw1, 24, (1, 1), (1, 1), Padding::Same, Activation::Relu);
    let dw2 = b.dwconv2d(format!("{n}/dw2"), pw1, (5, 5), (1, 1), Padding::Same, Activation::None);
    let act = if stride == 1 && in_c == out_c {
        Activation::None
    } else {
        Activation::Relu
    };
    let pw2 = b.conv2d(format!("{n}/pw2"), dw2, out_c, (1, 1), (1, 1), Padding::Same, act);
    if stride == 1 && in_c == out_c {
        b.add(format!("{n}/add"), x, pw2, Activation::Relu)
    } else {
        pw2
    }
}

/// Build BlazeFace at batch 1, f32.
pub fn blazeface() -> Graph {
    let mut b = GraphBuilder::new("blazeface", DType::F32);
    let x = b.input("input", vec![1, 128, 128, 3]);
    let mut h = b.conv2d("conv1", x, 24, (5, 5), (2, 2), Padding::Same, Activation::Relu); // 64²×24
    h = blaze_block(&mut b, "bb1", h, 28, 1); // channel-up: no residual
    h = blaze_block(&mut b, "bb2", h, 48, 2); // 32²×48
    h = blaze_block(&mut b, "bb3", h, 48, 1);
    h = double_blaze_block(&mut b, "dbb1", h, 96, 2); // 16²×96
    let feat16 = double_blaze_block(&mut b, "dbb2", h, 96, 1);
    let mut h8 = double_blaze_block(&mut b, "dbb3", feat16, 96, 2); // 8²×96
    h8 = double_blaze_block(&mut b, "dbb4", h8, 96, 1);
    let feat8 = h8;

    // SSD-style heads: 2 anchors at 16×16, 6 anchors at 8×8;
    // 1 score + 16 regression values per anchor.
    let cls16 = b.conv2d("head16/cls", feat16, 2, (3, 3), (1, 1), Padding::Same, Activation::None);
    let reg16 = b.conv2d("head16/reg", feat16, 32, (3, 3), (1, 1), Padding::Same, Activation::None);
    let cls8 = b.conv2d("head8/cls", feat8, 6, (3, 3), (1, 1), Padding::Same, Activation::None);
    let reg8 = b.conv2d("head8/reg", feat8, 96, (3, 3), (1, 1), Padding::Same, Activation::None);
    let cls16f = b.reshape("head16/cls_flat", cls16, vec![1, 512]);
    let reg16f = b.reshape("head16/reg_flat", reg16, vec![1, 8192]);
    let cls8f = b.reshape("head8/cls_flat", cls8, vec![1, 384]);
    let reg8f = b.reshape("head8/reg_flat", reg8, vec![1, 6144]);
    let scores = b.concat("scores", &[cls16f, cls8f]);
    let boxes = b.concat("boxes", &[reg16f, reg8f]);
    b.mark_output(scores);
    b.mark_output(boxes);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = blazeface();
        assert_eq!(g.outputs.len(), 2);
        let recs = UsageRecords::from_graph(&g);
        assert!(recs.len() > 30);
        // residual adds exist
        assert!(g.ops.iter().any(|o| o.name.ends_with("/add")));
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper: Naive = 2.698 MiB; see module docs for why we assert a
        // window rather than an exact match.
        let g = blazeface();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (2.2..3.4).contains(&naive),
            "naive = {naive:.3} MiB, expected ~2.7 (paper: 2.698)"
        );
    }

    #[test]
    fn lower_bound_is_near_paper() {
        // Paper Table 2 lower bound: 0.492 MiB; our widest profile is the
        // first blaze block (conv1 + its dw output) ≈ 0.75 MiB.
        let g = blazeface();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (0.4..0.95).contains(&lb),
            "offset lower bound = {lb:.4} MiB (paper: 0.492)"
        );
    }
}
