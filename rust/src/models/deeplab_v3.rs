//! DeepLab v3 (Chen et al. 2017), 257×257×3, MobileNet-v2 backbone —
//! Table 1/2 column 3.
//!
//! This is the mobile segmentation model TFLite ships (the paper's authors
//! work on the TFLite GPU delegate, whose demo model is
//! `deeplabv3_257_mv_gpu`): a MobileNet v2 feature extractor run at output
//! stride 16 (final stage dilated instead of strided), an ASPP head with a
//! 1×1 branch and a global-pooling branch, and a bilinear upsample back to
//! the input resolution. The long-lived 33×33 backbone tensors bridged
//! across the ASPP branches are why every strategy beats prior work by the
//! largest margin here (Table 1).

use super::mobilenet_v2::v2_backbone;
use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding};

/// Build DeepLab v3 (MobileNet-v2 backbone, 21 PASCAL classes) at batch 1.
pub fn deeplab_v3() -> Graph {
    let mut b = GraphBuilder::new("deeplab_v3", DType::F32);
    // Backbone at output stride 16: 257 -> 17×17×320.
    let feat = v2_backbone(&mut b, 257, 16);
    let hw = b.shape(feat)[1];

    // ASPP, mobile variant: 1×1 conv branch + image-level pooling branch.
    let aspp1 = b.conv2d(
        "aspp/conv1x1",
        feat,
        256,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::Relu,
    );
    let pooled = b.global_avg_pool("aspp/image_pool", feat);
    let pooled = b.conv2d(
        "aspp/image_pool_conv",
        pooled,
        256,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::Relu,
    );
    let pooled_up = b.resize_bilinear("aspp/image_pool_upsample", pooled, (hw, hw));
    let fused = b.concat("aspp/concat", &[aspp1, pooled_up]);
    let proj = b.conv2d(
        "aspp/project",
        fused,
        256,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::Relu,
    );

    // Per-pixel classifier + upsample to input resolution.
    let logits = b.conv2d(
        "classifier",
        proj,
        21,
        (1, 1),
        (1, 1),
        Padding::Same,
        Activation::None,
    );
    let up = b.resize_bilinear("upsample_logits", logits, (257, 257));
    b.mark_output(up);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = deeplab_v3();
        assert_eq!(g.tensor(g.outputs[0]).shape, vec![1, 257, 257, 21]);
        // The dilated stage must exist: some dwconv carries dilation 2.
        let dilated = g.ops.iter().any(|o| {
            matches!(
                o.kind,
                crate::graph::OpKind::DepthwiseConv2d { dilation: (2, 2), .. }
            )
        });
        assert!(dilated, "output-stride-16 backbone must dilate");
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper: Naive = 48.642 MiB.
        let g = deeplab_v3();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (naive - 48.642).abs() / 48.642 < 0.15,
            "naive = {naive:.3} MiB, paper says 48.642"
        );
    }

    #[test]
    fn lower_bound_is_near_paper() {
        // Paper Table 2 lower bound: 4.320 MiB. Our full-width (1.0×)
        // MobileNet-v2 backbone at 257×257 makes the block-2 expansion
        // tensor (129²×96) dominate at 7.6 MiB; the authors' converted model
        // evidently thins this stage. Pin our value; paper-vs-ours deltas
        // live in EXPERIMENTS.md.
        let g = deeplab_v3();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (7.0..8.3).contains(&lb),
            "offset lower bound = {lb:.4} MiB, expected ~7.6 (paper graph: 4.320)"
        );
    }
}
