//! The paper's Figure 1 example network.
//!
//! The figure itself is not machine-readable, but the running text pins it
//! down tightly; this fixture satisfies every stated fact *exactly*:
//!
//! * nine tensors #0–#8, of which #0–#7 are intermediates and **#8 is not an
//!   intermediate tensor** (it is the network output) — Figure 1 caption;
//! * tensor #2's usage record is `{first_op=1, last_op=3, size=36}` —
//!   Figure 1(b)/2(a);
//! * operator #3's profile is `{36, 28, 16}` with breadth
//!   `36 + 28 + 16 = 80` — §3;
//! * the third positional maximum is `max(16, 16, 16, 10) = 16`, i.e.
//!   exactly four operator profiles have a third element and their values
//!   are 16, 16, 16, 10 — §3.
//!
//! Layout (tensor: interval, size in the figure's abstract units):
//!
//! ```text
//! op0: input        -> t0 (0-1, 32)
//! op1: t0           -> t1 (1-2, 16), t2 (1-3, 36)     [branch]
//! op2: t1           -> t3 (2-3, 28)
//! op3: t2, t3       -> t4 (3-4, 16)                   [merge]
//! op4: t4           -> t5 (4-5, 64)
//! op5: t5           -> t6 (5-6, 40), t7 (5-6, 10)     [branch]
//! op6: t6, t7       -> t8 (output)                    [merge]
//! ```
//!
//! Derived quantities used across the test suite: positional maximums
//! `[64, 40, 16]`; Shared-Objects lower bound 120; operator breadths
//! `[32, 84, 80, 80, 80, 114, 50]`; Offset lower bound 114; Naive 242.

use crate::graph::{DType, Graph, Op, OpId, OpKind, Tensor, TensorId, TensorKind};
use crate::records::UsageRecords;

/// One abstract size unit of the figure, in bytes. The paper's `size_t` is
/// an *aligned* byte size, so the unit equals our alignment quantum.
pub const EXAMPLE_UNIT: usize = crate::TENSOR_ALIGNMENT;

/// Figure 1(a) tensor sizes in abstract units, indexed by tensor id 0–7.
const SIZES: [usize; 8] = [32, 16, 36, 28, 16, 64, 40, 10];

/// The Figure-1 example network as a [`Graph`] (tensor sizes scaled by
/// [`EXAMPLE_UNIT`] so that aligned byte sizes reproduce the figure's units
/// exactly).
pub fn example_net() -> Graph {
    let mut tensors = Vec::new();
    let t = |name: &str, units: usize, kind: TensorKind, tensors: &mut Vec<Tensor>| {
        let id = TensorId(tensors.len());
        tensors.push(Tensor {
            id,
            name: name.to_string(),
            shape: vec![units * EXAMPLE_UNIT],
            dtype: DType::U8,
            kind,
        });
        id
    };
    // Tensor ids follow the figure: #0..#7 intermediates, #8 output, then
    // the graph input (which the figure does not number).
    let t0 = t("t0", SIZES[0], TensorKind::Intermediate, &mut tensors);
    let t1 = t("t1", SIZES[1], TensorKind::Intermediate, &mut tensors);
    let t2 = t("t2", SIZES[2], TensorKind::Intermediate, &mut tensors);
    let t3 = t("t3", SIZES[3], TensorKind::Intermediate, &mut tensors);
    let t4 = t("t4", SIZES[4], TensorKind::Intermediate, &mut tensors);
    let t5 = t("t5", SIZES[5], TensorKind::Intermediate, &mut tensors);
    let t6 = t("t6", SIZES[6], TensorKind::Intermediate, &mut tensors);
    let t7 = t("t7", SIZES[7], TensorKind::Intermediate, &mut tensors);
    let t8 = t("t8", 8, TensorKind::Output, &mut tensors);
    let input = t("input", 32, TensorKind::Input, &mut tensors);

    let op = |i: usize, name: &str, inputs: Vec<TensorId>, outputs: Vec<TensorId>| Op {
        id: OpId(i),
        name: name.to_string(),
        kind: OpKind::Elementwise { name: "EXAMPLE" },
        inputs,
        outputs,
    };
    let ops = vec![
        op(0, "op0", vec![input], vec![t0]),
        op(1, "op1", vec![t0], vec![t1, t2]),
        op(2, "op2", vec![t1], vec![t3]),
        op(3, "op3", vec![t2, t3], vec![t4]),
        op(4, "op4", vec![t4], vec![t5]),
        op(5, "op5", vec![t5], vec![t6, t7]),
        op(6, "op6", vec![t6, t7], vec![t8]),
    ];

    let g = Graph {
        name: "example".into(),
        tensors,
        ops,
        inputs: vec![input],
        outputs: vec![t8],
    };
    g.validate().expect("example net must validate");
    g
}

/// The Figure 2(a) usage records in the figure's abstract units (sizes
/// 32, 16, 36, ... rather than bytes). Most planner unit tests work on
/// these directly.
pub fn example_records() -> UsageRecords {
    let g = example_net();
    let mut recs = UsageRecords::from_graph(&g);
    for r in &mut recs.records {
        debug_assert_eq!(r.size % EXAMPLE_UNIT, 0);
        r.size /= EXAMPLE_UNIT;
    }
    recs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure_1b_tensor_2_record() {
        let recs = example_records();
        let r2 = recs.records[2];
        assert_eq!((r2.first_op, r2.last_op, r2.size), (1, 3, 36));
    }

    #[test]
    fn eight_intermediates_and_output_excluded() {
        let recs = example_records();
        assert_eq!(recs.len(), 8);
        assert_eq!(recs.num_ops, 7);
        let sizes: Vec<usize> = recs.records.iter().map(|r| r.size).collect();
        assert_eq!(sizes, SIZES.to_vec());
        assert_eq!(recs.naive_total(), 242);
    }

    #[test]
    fn graph_scaled_sizes_are_aligned_units() {
        let g = example_net();
        let recs = UsageRecords::from_graph(&g);
        for (r, &u) in recs.records.iter().zip(SIZES.iter()) {
            assert_eq!(r.size, u * EXAMPLE_UNIT);
        }
    }
}
