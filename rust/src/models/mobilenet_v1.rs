//! MobileNet v1 (Howard et al. 2017), 224×224×3, width multiplier 1.0 —
//! Table 1/2 column 1.
//!
//! Calibration note: the paper's lower bound for this network, 4.594 MiB,
//! equals exactly `112·112·32·4 (dw1 output) + 112·112·64·4 (pw1 output)`
//! = 1.531 + 3.063 MiB — the breadth of the first pointwise convolution.
//! Our reconstruction reproduces that operator profile, so the lower-bound
//! row of EXPERIMENTS.md matches the paper to the kilobyte.

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding};

/// `(out_channels_of_pointwise, stride_of_depthwise)` for the 13 separable
/// blocks of Table 1 in the MobileNet paper.
const BLOCKS: [(usize, usize); 13] = [
    (64, 1),
    (128, 2),
    (128, 1),
    (256, 2),
    (256, 1),
    (512, 2),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (512, 1),
    (1024, 2),
    (1024, 1),
];

/// Build MobileNet v1 at batch 1, f32.
pub fn mobilenet_v1() -> Graph {
    let mut b = GraphBuilder::new("mobilenet_v1", DType::F32);
    let x = b.input("input", vec![1, 224, 224, 3]);
    let mut h = b.conv2d(
        "conv1",
        x,
        32,
        (3, 3),
        (2, 2),
        Padding::Same,
        Activation::Relu6,
    );
    for (i, &(out_c, stride)) in BLOCKS.iter().enumerate() {
        h = b.dwconv2d(
            format!("block{}/dw", i + 1),
            h,
            (3, 3),
            (stride, stride),
            Padding::Same,
            Activation::Relu6,
        );
        h = b.conv2d(
            format!("block{}/pw", i + 1),
            h,
            out_c,
            (1, 1),
            (1, 1),
            Padding::Same,
            Activation::Relu6,
        );
    }
    let g = b.global_avg_pool("avg_pool", h);
    let flat = b.reshape("flatten", g, vec![1, 1024]);
    let logits = b.fully_connected("fc", flat, 1001, Activation::None);
    let probs = b.softmax("softmax", logits);
    b.mark_output(probs);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = mobilenet_v1();
        // conv1 + 13*(dw+pw) + gap + reshape + fc + softmax = 31 ops
        assert_eq!(g.num_ops(), 31);
        assert_eq!(g.tensor(g.outputs[0]).shape, vec![1, 1001]);
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper Table 1: Naive = 19.248 MiB. Our reconstruction must land
        // within a few percent (converter-level op fusion differs).
        let g = mobilenet_v1();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (naive - 19.248).abs() / 19.248 < 0.10,
            "naive = {naive:.3} MiB, paper says 19.248"
        );
    }

    #[test]
    fn lower_bound_matches_paper_exactly() {
        // Paper: Offset lower bound 4.594 MiB = breadth of block1/pw.
        let g = mobilenet_v1();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (lb - 4.594).abs() < 0.002,
            "offset lower bound = {lb:.4} MiB, paper says 4.594"
        );
    }
}
