//! Model zoo: the paper's six evaluation networks rebuilt layer-by-layer,
//! plus the Figure-1 example network.
//!
//! The paper evaluates its planners on MobileNet v1, MobileNet v2,
//! DeepLab v3, Inception v3, PoseNet, and BlazeFace at 32-bit floats
//! (Table 1 / Table 2). The authors used the TFLite flatbuffers of those
//! models; we reconstruct each architecture from its original publication so
//! that the planner input — the multiset of tensor usage records — matches
//! the paper's up to converter-level differences (op fusion, pad handling).
//! Absolute megabytes therefore differ slightly from the tables; the
//! *relational* claims (which strategy wins, lower-bound attainment, naive
//! ratios) are what EXPERIMENTS.md checks.

mod blazeface;
mod deeplab_v3;
mod example;
mod inception_v3;
mod l2_cnn;
mod mobilenet_v1;
mod mobilenet_v2;
mod posenet;

pub use blazeface::blazeface;
pub use deeplab_v3::deeplab_v3;
pub use example::{example_net, example_records, EXAMPLE_UNIT};
pub use inception_v3::inception_v3;
pub use l2_cnn::{l2_cnn, L2_CLASSES, L2_HW};
pub use mobilenet_v1::mobilenet_v1;
pub use mobilenet_v2::mobilenet_v2;
pub use posenet::posenet;

use crate::graph::{DType, Graph};

/// Re-type every activation/weight tensor of a graph (e.g. plan the zoo at
/// F16 or U8 — the quantized-deployment planning study). Alignment makes
/// footprints *not* scale exactly with element size: a 10-byte U8 tensor
/// still occupies one 64-byte slot, so small-tensor-heavy nets (BlazeFace)
/// shrink less than 4×.
pub fn with_dtype(graph: &Graph, dtype: DType) -> Graph {
    let mut g = graph.clone();
    g.name = format!("{}_{dtype:?}", g.name).to_lowercase();
    for t in &mut g.tensors {
        t.dtype = dtype;
    }
    g
}

/// Names of the six evaluation networks, in the tables' column order.
pub const ZOO: [&str; 6] = [
    "mobilenet_v1",
    "mobilenet_v2",
    "deeplab_v3",
    "inception_v3",
    "posenet",
    "blazeface",
];

/// Construct a zoo network by name (batch size 1, f32).
pub fn by_name(name: &str) -> Option<Graph> {
    match name {
        "mobilenet_v1" => Some(mobilenet_v1()),
        "mobilenet_v2" => Some(mobilenet_v2()),
        "deeplab_v3" => Some(deeplab_v3()),
        "inception_v3" => Some(inception_v3()),
        "posenet" => Some(posenet()),
        "blazeface" => Some(blazeface()),
        "example" => Some(example_net()),
        "l2_cnn" => Some(l2_cnn()),
        _ => None,
    }
}

/// All six zoo graphs in table order.
pub fn all_zoo() -> Vec<Graph> {
    ZOO.iter().map(|n| by_name(n).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_constructs_and_validates() {
        for name in ZOO {
            let g = by_name(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(g.validate().is_ok(), "{name} invalid");
            assert!(g.num_ops() > 5, "{name} too small");
            assert!(g.naive_intermediate_bytes() > 0);
        }
    }

    #[test]
    fn by_name_rejects_unknown() {
        assert!(by_name("resnet9000").is_none());
    }

    #[test]
    fn with_dtype_rescales_but_alignment_floors() {
        use crate::records::UsageRecords;
        let g = mobilenet_v1();
        let f32_naive = UsageRecords::from_graph(&g).naive_total();
        let f16 = with_dtype(&g, DType::F16);
        let f16_naive = UsageRecords::from_graph(&f16).naive_total();
        let u8g = with_dtype(&g, DType::U8);
        let u8_naive = UsageRecords::from_graph(&u8g).naive_total();
        // Large tensors dominate MobileNet: close to exact 2x / 4x.
        assert!((f32_naive as f64 / f16_naive as f64 - 2.0).abs() < 0.01);
        assert!((f32_naive as f64 / u8_naive as f64 - 4.0).abs() < 0.02);
        // But never better than the alignment floor.
        assert!(f16_naive * 2 >= f32_naive);
        // Planning still works and validates.
        use crate::planner::{offset::GreedyBySize, OffsetPlanner};
        let recs = UsageRecords::from_graph(&u8g);
        GreedyBySize.plan(&recs).validate(&recs).unwrap();
    }
}
