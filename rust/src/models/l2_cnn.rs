//! Rust twin of the L2 JAX serving model (`python/compile/model.py`).
//!
//! The serving example runs the AOT-compiled JAX model through PJRT; this
//! graph mirrors its architecture op-for-op so that (a) the planner can
//! size the serving arena, (b) the CPU executor can cross-check the memory
//! plan behaviourally, and (c) the planner tables can include the model we
//! actually serve. Keep in sync with `python/compile/model.py`.

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding};

/// Input spatial size of the serving CNN.
pub const L2_HW: usize = 32;
/// Classes of the serving CNN.
pub const L2_CLASSES: usize = 10;

/// MobileNet-v1-flavoured classifier: conv stem + 4 depthwise-separable
/// blocks + GAP + FC, 32×32×3 → 10 classes (batch 1; PJRT variants handle
/// real batches).
pub fn l2_cnn() -> Graph {
    let mut b = GraphBuilder::new("l2_cnn", DType::F32);
    let x = b.input("input", vec![1, L2_HW, L2_HW, 3]);
    let mut h = b.conv2d("stem", x, 16, (3, 3), (1, 1), Padding::Same, Activation::Relu6);
    for (i, &(c, s)) in [(32, 2), (32, 1), (64, 2), (64, 1)].iter().enumerate() {
        h = b.dwconv2d(
            format!("block{i}/dw"),
            h,
            (3, 3),
            (s, s),
            Padding::Same,
            Activation::Relu6,
        );
        h = b.conv2d(
            format!("block{i}/pw"),
            h,
            c,
            (1, 1),
            (1, 1),
            Padding::Same,
            Activation::Relu6,
        );
    }
    let g = b.global_avg_pool("gap", h);
    let flat = b.reshape("flatten", g, vec![1, 64]);
    let logits = b.fully_connected("fc", flat, L2_CLASSES, Activation::None);
    let probs = b.softmax("softmax", logits);
    b.mark_output(probs);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::planner::OffsetPlanner;
    use crate::records::UsageRecords;

    #[test]
    fn structure_matches_python_model() {
        let g = l2_cnn();
        // stem + 4*(dw+pw) + gap + flatten + fc + softmax = 13 ops
        assert_eq!(g.num_ops(), 13);
        assert_eq!(g.tensor(g.inputs[0]).shape, vec![1, 32, 32, 3]);
        assert_eq!(g.tensor(g.outputs[0]).shape, vec![1, 10]);
    }

    #[test]
    fn planning_beats_naive() {
        let g = l2_cnn();
        let recs = UsageRecords::from_graph(&g);
        let plan = crate::planner::offset::GreedyBySize.plan(&recs);
        plan.validate(&recs).unwrap();
        assert!(plan.total_size() * 2 < recs.naive_total());
    }
}
