//! Inception v3 (Szegedy et al. 2016), 299×299×3 — Table 1/2 column 4.
//!
//! The concat-heavy Inception blocks produce the deepest operator profiles
//! of the zoo (many simultaneously-live branch tensors), which is what makes
//! this network the paper's largest Table-1 gap between Greedy (Lee 2019)
//! at 12.703 MiB and Greedy by Size at 10.337 MiB.

use crate::graph::{Activation, DType, Graph, GraphBuilder, Padding, PoolKind, TensorId};

const RELU: Activation = Activation::Relu;

/// conv + BN + ReLU (BN folds into the conv at inference, TFLite-style).
fn conv(
    b: &mut GraphBuilder,
    name: String,
    x: TensorId,
    out_c: usize,
    k: (usize, usize),
    s: (usize, usize),
    p: Padding,
) -> TensorId {
    b.conv2d(name, x, out_c, k, s, p, RELU)
}

/// 35×35 Inception-A block (5x5 branch factorized per the v3 paper).
fn inception_a(b: &mut GraphBuilder, n: &str, x: TensorId, pool_c: usize) -> TensorId {
    let b1 = conv(b, format!("{n}/b1/1x1"), x, 64, (1, 1), (1, 1), Padding::Same);
    let b5 = conv(b, format!("{n}/b5/1x1"), x, 48, (1, 1), (1, 1), Padding::Same);
    let b5 = conv(b, format!("{n}/b5/5x5"), b5, 64, (5, 5), (1, 1), Padding::Same);
    let b3 = conv(b, format!("{n}/b3/1x1"), x, 64, (1, 1), (1, 1), Padding::Same);
    let b3 = conv(b, format!("{n}/b3/3x3a"), b3, 96, (3, 3), (1, 1), Padding::Same);
    let b3 = conv(b, format!("{n}/b3/3x3b"), b3, 96, (3, 3), (1, 1), Padding::Same);
    let bp = b.pool2d(
        format!("{n}/pool"),
        x,
        PoolKind::Average,
        (3, 3),
        (1, 1),
        Padding::Same,
    );
    let bp = conv(b, format!("{n}/pool/1x1"), bp, pool_c, (1, 1), (1, 1), Padding::Same);
    b.concat(format!("{n}/concat"), &[b1, b5, b3, bp])
}

/// 35→17 Reduction-A.
fn reduction_a(b: &mut GraphBuilder, n: &str, x: TensorId) -> TensorId {
    let b3 = conv(b, format!("{n}/b3/3x3"), x, 384, (3, 3), (2, 2), Padding::Valid);
    let bd = conv(b, format!("{n}/bd/1x1"), x, 64, (1, 1), (1, 1), Padding::Same);
    let bd = conv(b, format!("{n}/bd/3x3a"), bd, 96, (3, 3), (1, 1), Padding::Same);
    let bd = conv(b, format!("{n}/bd/3x3b"), bd, 96, (3, 3), (2, 2), Padding::Valid);
    let bp = b.pool2d(
        format!("{n}/pool"),
        x,
        PoolKind::Max,
        (3, 3),
        (2, 2),
        Padding::Valid,
    );
    b.concat(format!("{n}/concat"), &[b3, bd, bp])
}

/// 17×17 Inception-B block with factorized 7×7 convs; `c7` is the
/// bottleneck width (128/160/192 across the four blocks).
fn inception_b(b: &mut GraphBuilder, n: &str, x: TensorId, c7: usize) -> TensorId {
    let b1 = conv(b, format!("{n}/b1/1x1"), x, 192, (1, 1), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/1x1"), x, c7, (1, 1), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/1x7"), b7, c7, (1, 7), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/7x1"), b7, 192, (7, 1), (1, 1), Padding::Same);
    let bb = conv(b, format!("{n}/bb/1x1"), x, c7, (1, 1), (1, 1), Padding::Same);
    let bb = conv(b, format!("{n}/bb/7x1a"), bb, c7, (7, 1), (1, 1), Padding::Same);
    let bb = conv(b, format!("{n}/bb/1x7a"), bb, c7, (1, 7), (1, 1), Padding::Same);
    let bb = conv(b, format!("{n}/bb/7x1b"), bb, c7, (7, 1), (1, 1), Padding::Same);
    let bb = conv(b, format!("{n}/bb/1x7b"), bb, 192, (1, 7), (1, 1), Padding::Same);
    let bp = b.pool2d(
        format!("{n}/pool"),
        x,
        PoolKind::Average,
        (3, 3),
        (1, 1),
        Padding::Same,
    );
    let bp = conv(b, format!("{n}/pool/1x1"), bp, 192, (1, 1), (1, 1), Padding::Same);
    b.concat(format!("{n}/concat"), &[b1, b7, bb, bp])
}

/// 17→8 Reduction-B.
fn reduction_b(b: &mut GraphBuilder, n: &str, x: TensorId) -> TensorId {
    let b3 = conv(b, format!("{n}/b3/1x1"), x, 192, (1, 1), (1, 1), Padding::Same);
    let b3 = conv(b, format!("{n}/b3/3x3"), b3, 320, (3, 3), (2, 2), Padding::Valid);
    let b7 = conv(b, format!("{n}/b7/1x1"), x, 192, (1, 1), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/1x7"), b7, 192, (1, 7), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/7x1"), b7, 192, (7, 1), (1, 1), Padding::Same);
    let b7 = conv(b, format!("{n}/b7/3x3"), b7, 192, (3, 3), (2, 2), Padding::Valid);
    let bp = b.pool2d(
        format!("{n}/pool"),
        x,
        PoolKind::Max,
        (3, 3),
        (2, 2),
        Padding::Valid,
    );
    b.concat(format!("{n}/concat"), &[b3, b7, bp])
}

/// 8×8 Inception-C block (branch outputs themselves fan out and concat).
fn inception_c(b: &mut GraphBuilder, n: &str, x: TensorId) -> TensorId {
    let b1 = conv(b, format!("{n}/b1/1x1"), x, 320, (1, 1), (1, 1), Padding::Same);
    let b3 = conv(b, format!("{n}/b3/1x1"), x, 384, (1, 1), (1, 1), Padding::Same);
    let b3a = conv(b, format!("{n}/b3/1x3"), b3, 384, (1, 3), (1, 1), Padding::Same);
    let b3b = conv(b, format!("{n}/b3/3x1"), b3, 384, (3, 1), (1, 1), Padding::Same);
    let bd = conv(b, format!("{n}/bd/1x1"), x, 448, (1, 1), (1, 1), Padding::Same);
    let bd = conv(b, format!("{n}/bd/3x3"), bd, 384, (3, 3), (1, 1), Padding::Same);
    let bda = conv(b, format!("{n}/bd/1x3"), bd, 384, (1, 3), (1, 1), Padding::Same);
    let bdb = conv(b, format!("{n}/bd/3x1"), bd, 384, (3, 1), (1, 1), Padding::Same);
    let bp = b.pool2d(
        format!("{n}/pool"),
        x,
        PoolKind::Average,
        (3, 3),
        (1, 1),
        Padding::Same,
    );
    let bp = conv(b, format!("{n}/pool/1x1"), bp, 192, (1, 1), (1, 1), Padding::Same);
    b.concat(format!("{n}/concat"), &[b1, b3a, b3b, bda, bdb, bp])
}

/// Build Inception v3 at batch 1, f32.
pub fn inception_v3() -> Graph {
    let mut b = GraphBuilder::new("inception_v3", DType::F32);
    let x = b.input("input", vec![1, 299, 299, 3]);
    // Stem.
    let mut h = conv(&mut b, "stem/conv1".into(), x, 32, (3, 3), (2, 2), Padding::Valid); // 149
    h = conv(&mut b, "stem/conv2".into(), h, 32, (3, 3), (1, 1), Padding::Valid); // 147
    h = conv(&mut b, "stem/conv3".into(), h, 64, (3, 3), (1, 1), Padding::Same); // 147
    h = b.pool2d("stem/pool1", h, PoolKind::Max, (3, 3), (2, 2), Padding::Valid); // 73
    h = conv(&mut b, "stem/conv4".into(), h, 80, (1, 1), (1, 1), Padding::Valid); // 73
    h = conv(&mut b, "stem/conv5".into(), h, 192, (3, 3), (1, 1), Padding::Valid); // 71
    h = b.pool2d("stem/pool2", h, PoolKind::Max, (3, 3), (2, 2), Padding::Valid); // 35
    // 3 × Inception-A.
    h = inception_a(&mut b, "mixed0", h, 32);
    h = inception_a(&mut b, "mixed1", h, 64);
    h = inception_a(&mut b, "mixed2", h, 64);
    // Reduction-A -> 17×17×768.
    h = reduction_a(&mut b, "mixed3", h);
    // 4 × Inception-B.
    h = inception_b(&mut b, "mixed4", h, 128);
    h = inception_b(&mut b, "mixed5", h, 160);
    h = inception_b(&mut b, "mixed6", h, 160);
    h = inception_b(&mut b, "mixed7", h, 192);
    // Reduction-B -> 8×8×1280.
    h = reduction_b(&mut b, "mixed8", h);
    // 2 × Inception-C -> 8×8×2048.
    h = inception_c(&mut b, "mixed9", h);
    h = inception_c(&mut b, "mixed10", h);
    let g = b.global_avg_pool("avg_pool", h);
    let flat = b.reshape("flatten", g, vec![1, 2048]);
    let logits = b.fully_connected("fc", flat, 1001, Activation::None);
    let probs = b.softmax("softmax", logits);
    b.mark_output(probs);
    b.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::records::UsageRecords;

    const MIB: f64 = 1024.0 * 1024.0;

    #[test]
    fn structure() {
        let g = inception_v3();
        let recs = UsageRecords::from_graph(&g);
        assert!(recs.len() > 100, "{} intermediates", recs.len());
        // channel math: final concat is 2048 wide
        let gap = g.ops.iter().find(|o| o.name == "avg_pool").unwrap();
        assert_eq!(g.tensor(gap.inputs[0]).shape, vec![1, 8, 8, 2048]);
    }

    #[test]
    fn naive_total_matches_paper_scale() {
        // Paper: Naive = 54.010 MiB.
        let g = inception_v3();
        let naive = g.naive_intermediate_bytes() as f64 / MIB;
        assert!(
            (naive - 54.010).abs() / 54.010 < 0.10,
            "naive = {naive:.3} MiB, paper says 54.010"
        );
    }

    #[test]
    fn lower_bound_is_near_paper() {
        // Paper Table 2 lower bound: 7.914 MiB.
        let g = inception_v3();
        let recs = UsageRecords::from_graph(&g);
        let lb = recs.profiles().offset_lower_bound() as f64 / MIB;
        assert!(
            (lb - 7.914).abs() / 7.914 < 0.12,
            "offset lower bound = {lb:.4} MiB, paper says 7.914"
        );
    }
}
