//! PJRT runtime: load AOT-compiled JAX/Pallas models (HLO text) and execute
//! them from Rust.
//!
//! This is the compute half of the three-layer architecture: Python lowers
//! the L2 JAX model (with its L1 Pallas kernels) to HLO **text** once at
//! build time (`python/compile/aot.py` → `artifacts/*.hlo.txt`); the Rust
//! serving path loads the text, compiles it on the PJRT CPU client, and
//! executes batches with zero Python involvement.
//!
//! HLO text — not a serialized `HloModuleProto` — is the interchange format
//! because jax ≥ 0.5 emits protos with 64-bit instruction ids that the
//! pinned xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Working-set accounting does **not** live here: the serving engine over
//! these executables (`coordinator::engine::PjrtEngine`) takes a shared
//! `PlanService` handle plus a typed `PlanRequest` and resolves its
//! planned peaks, budget admission, and stats through the same plan cache
//! as the pure-Rust path — this module only compiles and runs batches.

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

/// A PJRT client; compiles HLO-text artifacts into executables.
pub struct Runtime {
    client: xla::PjRtClient,
}

/// One compiled executable: a model lowered at a fixed batch size.
pub struct CompiledModel {
    exe: xla::PjRtLoadedExecutable,
    /// Batch size this variant was lowered for.
    pub batch: usize,
    /// Flat input element count *per sample*.
    pub in_elems: usize,
    /// Flat output element count *per sample*.
    pub out_elems: usize,
    /// Input dims including batch, e.g. [batch, h, w, c].
    pub in_dims: Vec<usize>,
}

impl Runtime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client })
    }

    /// Backend name (e.g. "cpu") and device count, for logs.
    pub fn platform(&self) -> (String, usize) {
        (self.client.platform_name(), self.client.device_count())
    }

    /// Load and compile one HLO-text artifact. `in_dims` must match the
    /// shape the artifact was lowered with (`[batch, ...]`); `out_elems` is
    /// the per-sample output size.
    pub fn load_hlo_text(
        &self,
        path: &Path,
        in_dims: &[usize],
        out_elems: usize,
    ) -> Result<CompiledModel> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {path:?}: {e:?}"))?;
        let batch = in_dims[0];
        let in_elems: usize = in_dims[1..].iter().product();
        Ok(CompiledModel {
            exe,
            batch,
            in_elems,
            out_elems,
            in_dims: in_dims.to_vec(),
        })
    }

    /// Discover `model_b{N}.hlo.txt` variants in an artifact directory.
    /// Returns (batch, path) sorted by batch size.
    pub fn discover_variants(dir: &Path, stem: &str) -> Result<Vec<(usize, PathBuf)>> {
        let mut found = Vec::new();
        for entry in std::fs::read_dir(dir).with_context(|| format!("read {dir:?}"))? {
            let p = entry?.path();
            let name = p.file_name().and_then(|n| n.to_str()).unwrap_or("");
            if let Some(rest) = name
                .strip_prefix(&format!("{stem}_b"))
                .and_then(|r| r.strip_suffix(".hlo.txt"))
            {
                if let Ok(b) = rest.parse::<usize>() {
                    found.push((b, p));
                }
            }
        }
        if found.is_empty() {
            bail!("no {stem}_b*.hlo.txt artifacts in {dir:?}; run `make artifacts`");
        }
        found.sort();
        Ok(found)
    }
}

impl CompiledModel {
    /// Execute one batch. `input` must hold exactly `batch * in_elems`
    /// floats (callers pad partial batches); returns `batch * out_elems`
    /// floats.
    pub fn run(&self, input: &[f32]) -> Result<Vec<f32>> {
        if input.len() != self.batch * self.in_elems {
            bail!(
                "batch input has {} elems, executable wants {}",
                input.len(),
                self.batch * self.in_elems
            );
        }
        let dims: Vec<i64> = self.in_dims.iter().map(|&d| d as i64).collect();
        let lit = xla::Literal::vec1(input)
            .reshape(&dims)
            .map_err(|e| anyhow!("reshape input: {e:?}"))?;
        let result = self
            .exe
            .execute::<xla::Literal>(&[lit])
            .map_err(|e| anyhow!("execute: {e:?}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch output: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = out_lit
            .to_tuple1()
            .map_err(|e| anyhow!("untuple output: {e:?}"))?;
        let v = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("output to_vec: {e:?}"))?;
        if v.len() != self.batch * self.out_elems {
            bail!(
                "executable returned {} elems, expected {}",
                v.len(),
                self.batch * self.out_elems
            );
        }
        Ok(v)
    }
}

/// A set of batch-size variants of one model, with best-fit selection.
pub struct VariantSet {
    /// Sorted by batch ascending.
    pub variants: Vec<CompiledModel>,
}

impl VariantSet {
    /// Load all `stem_b*.hlo.txt` variants from `dir`. `sample_dims` are
    /// the per-sample input dims (without batch).
    pub fn load(rt: &Runtime, dir: &Path, stem: &str, sample_dims: &[usize], out_elems: usize) -> Result<Self> {
        let mut variants = Vec::new();
        for (b, path) in Runtime::discover_variants(dir, stem)? {
            let mut dims = vec![b];
            dims.extend_from_slice(sample_dims);
            variants.push(rt.load_hlo_text(&path, &dims, out_elems)?);
        }
        Ok(VariantSet { variants })
    }

    /// Smallest variant with `batch >= n`, or the largest if none fits.
    pub fn pick(&self, n: usize) -> &CompiledModel {
        self.variants
            .iter()
            .find(|v| v.batch >= n)
            .unwrap_or_else(|| self.variants.last().expect("no variants"))
    }

    /// Max supported batch.
    pub fn max_batch(&self) -> usize {
        self.variants.last().map(|v| v.batch).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_parses_and_sorts() {
        let dir = std::env::temp_dir().join(format!("ta_disc_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for b in [4, 1, 2] {
            std::fs::write(dir.join(format!("model_b{b}.hlo.txt")), "x").unwrap();
        }
        std::fs::write(dir.join("other.txt"), "x").unwrap();
        let found = Runtime::discover_variants(&dir, "model").unwrap();
        let batches: Vec<usize> = found.iter().map(|(b, _)| *b).collect();
        assert_eq!(batches, vec![1, 2, 4]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn discover_errors_when_empty() {
        let dir = std::env::temp_dir().join(format!("ta_empty_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        assert!(Runtime::discover_variants(&dir, "model").is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    // PJRT-backed tests live in rust/tests/pjrt_integration.rs (they need
    // the artifacts built by `make artifacts`).
}
